"""Multi-device suite: the process-backed runtime on real meshes.

Each ProcessRuntime worker is a fresh spawned interpreter; the parent's
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` is inherited
verbatim (repro.launch.xla_env.worker_env), so every worker re-lowers its
stages against the same 8-device table the driver planned with. The claim
under test: swapping the transport (threads -> processes) changes *nothing*
numerically, even when stages run on multi-device meshes —

* train: 4 stages on a 2-device data-parallel placement, 3 AdamW steps
  with global-norm clipping, bitwise (loss/grads/params/opt state) against
  the threaded session;
* serve: 2 stages on a (1, 2) model-parallel mesh (sequence-sharded KV
  cache), token streams identical to the threaded engine (which the serve
  suite already ties to the monolithic reference).
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import dataclasses
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[2] / "src"))

import numpy as np

STAGES, MICROBATCHES, BATCH, WIDTH = 4, 4, 16, 32
PROMPT_LEN = 8


def _graph(placement):
    from repro.core.graph import LogicalGraph

    g = LogicalGraph(placement)
    h = g.input("x", (BATCH, WIDTH), sbp="S(0)")
    labels = g.input("labels", (BATCH,), dtype="int32", sbp="S(0)")
    for i in range(STAGES):
        w = g.input(f"w{i}", (WIDTH, WIDTH))
        h = g.matmul(h, w, name=f"mm{i}")
        if i < STAGES - 1:
            h = g.unary(h, "relu", name=f"relu{i}")
    g.softmax_xent(h, labels, name="loss")
    return g


def train_processes_match_threads():
    from repro import api
    from repro.core.lowering import OptimizerSpec
    from repro.core.placement import Placement

    placement = Placement(("data",), (2,), device_kind="cpu")
    rng = np.random.default_rng(5)
    params = {f"w{i}": (rng.normal(size=(WIDTH, WIDTH)) * 0.5
                        ).astype(np.float32) for i in range(STAGES)}
    data = {"x": rng.normal(size=(BATCH, WIDTH)).astype(np.float32),
            "labels": rng.integers(0, WIDTH, (BATCH,)).astype(np.int32)}
    opt = OptimizerSpec.adamw(lr=1e-2, grad_clip=0.5)
    kw = dict(mode="train", stages=STAGES, num_microbatches=MICROBATCHES,
              optimizer=opt)
    st = api.compile(_graph(placement), runtime="threads",
                     params=dict(params), **kw)
    sp = api.compile(_graph(placement), runtime="processes",
                     params=dict(params), **kw)
    try:
        api.assert_sessions_match(sp, st, data, steps=3)
        assert int(sp.opt_state.step) == 3
        assert any(v > 0 for v in sp.executor.last_edge_bytes.values())
    finally:
        sp.close()
        st.close()
    print(f"train dp(2): {STAGES} stages x 3 AdamW steps bitwise across "
          f"process workers")


def serve_processes_match_threads():
    import jax

    from repro import api
    from repro.configs.registry import get_config
    from repro.models.model_zoo import build_model
    from repro.train.steps import plan_from_mesh

    cfg = get_config("qwen2.5-3b").reduced()
    cfg = dataclasses.replace(cfg, vocab_size=1000)
    mesh = jax.make_mesh((1, 2), ("data", "model"))
    params = build_model(cfg, plan_from_mesh(mesh)).init(
        jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    gens = [2, 4, 3]
    prompts = [rng.integers(0, cfg.vocab_size, (PROMPT_LEN,)).astype(
        np.int32) for _ in gens]
    kw = dict(mode="serve", stages=2, params=params, mesh=mesh,
              num_groups=2, group_size=1, max_prompt_len=PROMPT_LEN,
              max_new_tokens=max(gens))
    st = api.compile(cfg, runtime="threads", **kw)
    sp = api.compile(cfg, runtime="processes", **kw)
    try:
        ot = st.generate(list(zip(prompts, gens)))
        op = sp.generate(list(zip(prompts, gens)))
        for i, (a, b) in enumerate(zip(ot, op)):
            assert np.array_equal(a, b), (i, a, b)
    finally:
        sp.close()
        st.close()
    print(f"serve mp(1x2): {sum(gens)} tokens identical across process "
          f"workers")


if __name__ == "__main__":
    train_processes_match_threads()
    serve_processes_match_threads()
    print("ALL-OK")
