"""Multi-device suite: continuous-batching serve pipeline on real meshes.

Two placements a single-device test cannot reach:

* (1, 2) model-parallel: the KV cache is sequence-sharded over the model
  axis inside every stage (flash-decode partials combined with pmax/psum),
  and the stage-boundary hidden is replicated;
* (2, 1) data-parallel: the group cache is batch-sharded over the data axis
  while admission prefills (batch 1) run replicated and are scattered into
  the sharded group cache slot.

Both must be token-identical to the monolithic make_serve_step loop.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import dataclasses
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[2] / "src"))

import numpy as np

PROMPT_LEN = 8
CACHE_LEN = 16


def reference(cfg, mesh, params, prompts, gens):
    import jax
    import jax.numpy as jnp

    from repro.train.steps import greedy_from_logits, make_serve_step

    ss = make_serve_step(cfg, mesh, cache_len=CACHE_LEN)
    tokens = jnp.asarray(np.stack(prompts), jnp.int32)
    h_last, caches = ss.prefill_fn(params, {"tokens": tokens})
    tok = greedy_from_logits(ss.logits_fn(params, h_last), cfg.vocab_size)
    rows = [np.asarray(tok)]
    pos = jnp.full((len(prompts),), PROMPT_LEN, jnp.int32)
    for _ in range(max(gens) - 1):
        logits, caches = ss.decode_fn(params, caches, tok, pos)
        tok = greedy_from_logits(logits, cfg.vocab_size)
        rows.append(np.asarray(tok))
        pos = pos + 1
    mat = np.stack(rows, 1)
    return [mat[i, :g] for i, g in enumerate(gens)]


def run_mesh(mesh_shape, group_size, num_groups, gens, label):
    import jax

    from repro import api
    from repro.configs.registry import get_config
    from repro.models.model_zoo import build_model
    from repro.train.steps import plan_from_mesh

    cfg = get_config("qwen2.5-3b").reduced()
    cfg = dataclasses.replace(cfg, vocab_size=1000)   # padded vocab
    mesh = jax.make_mesh(mesh_shape, ("data", "model"))
    params = build_model(cfg, plan_from_mesh(mesh)).init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab_size, (PROMPT_LEN,)).astype(np.int32)
               for _ in gens]
    ref = reference(cfg, mesh, params, prompts, gens)

    sess = api.compile(cfg, mode="serve", backend="actors", stages=2,
                       params=params, mesh=mesh, num_groups=num_groups,
                       group_size=group_size, max_prompt_len=PROMPT_LEN,
                       max_new_tokens=max(gens), cache_len=CACHE_LEN)
    outs = sess.generate(list(zip(prompts, gens)))
    for i, (got, want) in enumerate(zip(outs, ref)):
        assert np.array_equal(got, want), (
            f"{label} request {i}: {got} != {want}")
    assert all((o < cfg.vocab_size).all() for o in outs)
    if num_groups * group_size < len(gens):
        assert sess.last_stats["admitted_mid_flight"] >= 1, label
    print(f"{label}: {sess.last_stats['tokens']} tokens token-identical "
          f"({sess.last_stats['admitted_mid_flight']} admitted mid-flight)")


def main():
    # model-parallel: seq-sharded KV cache, 3 requests through 2 slots
    run_mesh((1, 2), group_size=1, num_groups=2, gens=[2, 4, 3],
             label="mp(1x2)")
    # data-parallel: batch-sharded group cache, replicated admission prefill
    # (4 requests so the reference prefill batch divides the data axis)
    run_mesh((2, 1), group_size=2, num_groups=1, gens=[2, 4, 3, 1],
             label="dp(2x1)")


if __name__ == "__main__":
    main()
    print("ALL-OK")
