"""Paged-cache serving tests: the page pool, gather/scatter bit-identity
against the dense backend, chunked prefill, shared-prefix refcounting, and
the analytic cache-bytes accounting.

The dense PR-5 path is the bit-identity reference: greedy decode through
``cache="paged"`` must emit the exact token streams of ``cache="dense"``
on every backend/runtime combination, because a gathered page window
agrees with the dense group cache at every position a live request's
decode can observe.
"""
import dataclasses

import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro import api
from repro.configs.registry import get_config
from repro.models.model_zoo import build_model
from repro.serve.paged_cache import PagePool, PagedCacheSpec
from repro.train.steps import plan_from_mesh

PROMPT_LEN = 8
GENS = [3, 6, 2, 5, 4]
CACHE_LEN = 24
PAGE_LEN = 4
NUM_PAGES = 8


@pytest.fixture(scope="module")
def serve_env():
    cfg = get_config("qwen2.5-3b").reduced()
    cfg = dataclasses.replace(cfg, vocab_size=1000)   # padded head columns
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    params = build_model(cfg, plan_from_mesh(mesh)).init(
        jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size,
                            (PROMPT_LEN,)).astype(np.int32) for _ in GENS]
    return cfg, mesh, params, prompts


def _kw(params, mesh, **over):
    kw = dict(params=params, mesh=mesh, num_groups=2, group_size=1,
              max_prompt_len=PROMPT_LEN, max_new_tokens=max(GENS),
              cache_len=CACHE_LEN)
    kw.update(over)
    return kw


@pytest.fixture(scope="module")
def dense_ref(serve_env):
    """Dense monolithic greedy token streams: the bit-identity reference."""
    cfg, mesh, params, prompts = serve_env
    sess = api.compile(cfg, mode="serve", backend="monolithic",
                       **_kw(params, mesh))
    return sess.generate(list(zip(prompts, GENS)))


class TestPagePool:
    SPEC = PagedCacheSpec(page_len=4, num_pages=8, max_requests=4,
                          pages_per_req=6)

    def test_alloc_free_roundtrip(self):
        pool = PagePool(self.SPEC)
        row = pool.alloc(0, 3)
        assert (row >= 0).sum() == 3 and pool.free_count() == 5
        assert np.array_equal(pool.row(0), row)
        pool.free(0)
        assert pool.free_count() == 8
        assert (pool.page_table[0] == -1).all()

    def test_shared_pages_masked_in_write_row(self):
        pool = PagePool(self.SPEC)
        donor = pool.alloc(0, 2)
        row1 = pool.alloc(1, 1, shared=[int(donor[0])])
        # shared entry is mapped in the table but masked in the write row
        assert pool.page_table[1][0] == donor[0] and row1[0] == -1
        assert (row1 >= 0).sum() == 1
        assert pool.ref_counts[donor[0]] == 2

    def test_shared_pages_survive_donor_free(self):
        pool = PagePool(self.SPEC)
        donor = pool.alloc(0, 2)
        pool.alloc(1, 1, shared=[int(donor[0])])
        pool.free(0)
        # donor's private page returned, the shared one is still held
        assert pool.free_count() == 8 - 2
        assert pool.ref_counts[donor[0]] == 1
        pool.free(1)
        assert pool.free_count() == 8

    def test_double_alloc_and_exhaustion_raise(self):
        pool = PagePool(self.SPEC)
        pool.alloc(0, 3)
        with pytest.raises(ValueError, match="already mapped"):
            pool.alloc(0, 1)
        with pytest.raises(ValueError, match="exhausted"):
            pool.alloc(1, 6)          # <= pages_per_req but only 5 free
        with pytest.raises(ValueError, match="pages_per_req"):
            pool.alloc(2, 7)

    def test_rows_parks_negative_sids(self):
        pool = PagePool(self.SPEC)
        pool.alloc(2, 2)
        rows = pool.rows([-1, 2])
        assert (rows[0] == -1).all()
        assert np.array_equal(rows[1], pool.row(2))

    def test_peak_pages_tracks_high_water(self):
        pool = PagePool(self.SPEC)
        pool.alloc(0, 3)
        pool.alloc(1, 2)
        pool.free(0)
        assert pool.used_pages() == 2 and pool.peak_pages == 5


class TestPagedTokenIdentity:
    def test_monolithic_paged_matches_dense(self, serve_env, dense_ref):
        cfg, mesh, params, prompts = serve_env
        sess = api.compile(cfg, mode="serve", backend="monolithic",
                           cache="paged", page_len=PAGE_LEN,
                           num_pages=NUM_PAGES, **_kw(params, mesh))
        outs = sess.generate(list(zip(prompts, GENS)))
        for i, (got, ref) in enumerate(zip(outs, dense_ref)):
            assert np.array_equal(got, ref), f"request {i}: {got} != {ref}"
        stats = sess.last_stats
        assert 0 < stats["peak_pages"] <= NUM_PAGES
        assert "paged" in sess.describe()

    def test_actor_pipeline_paged_matches_dense(self, serve_env, dense_ref):
        cfg, mesh, params, prompts = serve_env
        with api.compile(cfg, mode="serve", backend="actors", stages=2,
                         cache="paged", page_len=PAGE_LEN,
                         num_pages=NUM_PAGES, **_kw(params, mesh)) as sess:
            outs = sess.generate(list(zip(prompts, GENS)))
        for i, (got, ref) in enumerate(zip(outs, dense_ref)):
            assert np.array_equal(got, ref), f"request {i}: {got} != {ref}"

    def test_process_runtime_paged_matches_dense(self, serve_env, dense_ref):
        """The page-table rows ride the work items and the slabs live in
        the stage worker processes — the pool itself never crosses a
        process boundary."""
        cfg, mesh, params, prompts = serve_env
        with api.compile(cfg, mode="serve", backend="actors", stages=2,
                         runtime="processes", cache="paged",
                         page_len=PAGE_LEN, num_pages=NUM_PAGES,
                         **_kw(params, mesh)) as sess:
            outs = sess.generate(list(zip(prompts, GENS)))
        for i, (got, ref) in enumerate(zip(outs, dense_ref)):
            assert np.array_equal(got, ref), f"request {i}: {got} != {ref}"

    def test_ssm_paged_matches_dense(self):
        """Recurrent state (SSM h, conv tails) lives in the per-request row
        pool, not the page slabs; paged serving must still match dense."""
        cfg = get_config("mamba2-370m").reduced()
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        params = build_model(cfg, plan_from_mesh(mesh)).init(
            jax.random.PRNGKey(0))
        rng = np.random.default_rng(2)
        reqs = [(rng.integers(0, cfg.vocab_size, (n,)).astype(np.int32), g)
                for n, g in ((5, 3), (8, 2), (6, 4))]
        kw = dict(params=params, mesh=mesh, num_groups=2, group_size=1,
                  max_prompt_len=8, max_new_tokens=4, cache_len=CACHE_LEN)
        ref = api.compile(cfg, mode="serve", backend="monolithic",
                          **kw).generate(reqs)
        with api.compile(cfg, mode="serve", backend="actors", stages=2,
                         cache="paged", page_len=4, num_pages=10,
                         **kw) as sess:
            outs = sess.generate(reqs)
        for i, (got, want) in enumerate(zip(outs, ref)):
            assert np.array_equal(got, want), f"ssm {i}: {got} != {want}"


class TestChunkedPrefill:
    def test_chunked_backends_agree(self, serve_env):
        """Chunked prefill is the same scan-of-decode program on every
        backend: monolithic and actor-pipeline streams must be identical,
        and prompts longer than the chunk land over multiple rounds."""
        cfg, mesh, params, prompts = serve_env
        kw = dict(cache="paged", page_len=PAGE_LEN, num_pages=NUM_PAGES,
                  prefill_chunk=3)
        mono = api.compile(cfg, mode="serve", backend="monolithic",
                           **kw, **_kw(params, mesh))
        a = mono.generate(list(zip(prompts, GENS)))
        with api.compile(cfg, mode="serve", backend="actors", stages=2,
                         **kw, **_kw(params, mesh)) as sess:
            b = sess.generate(list(zip(prompts, GENS)))
        for i, (x, y) in enumerate(zip(a, b)):
            assert np.array_equal(x, y), f"request {i}: {x} != {y}"
        assert [len(o) for o in a] == GENS
        assert all((o < cfg.vocab_size).all() and (o >= 0).all() for o in a)
        # 8-token prompts at chunk 3 need 3 chunk rounds before their first
        # token, so the session runs strictly more rounds than unchunked
        assert mono.last_stats["rounds"] > max(GENS) + 1

    def test_chunks_interleave_with_decode(self, serve_env):
        """A long prompt admitted mid-flight must not stall live decoding:
        rounds containing its chunks still carry decode work."""
        from repro.serve.admission import AdmissionScheduler
        from repro.serve.paged_cache import PagePool, PagedCacheSpec
        from repro.runtime.pipeline import DecodeWork, PrefillChunkWork

        spec = PagedCacheSpec(page_len=PAGE_LEN, num_pages=NUM_PAGES,
                              max_requests=2, pages_per_req=6)
        prompts = [np.arange(2, dtype=np.int32),
                   np.arange(8, dtype=np.int32)]
        sched = AdmissionScheduler(prompts, [6, 2], num_groups=2,
                                   group_size=1, cache_len=CACHE_LEN,
                                   pool=PagePool(spec), prefill_chunk=3)
        work, meta = sched.plan_round()     # prefill r0 + 1st chunk of r1
        kinds = [type(w).__name__ for w in work]
        assert kinds == ["PrefillWork", "PrefillChunkWork"]
        sched.absorb(meta[0], np.asarray([5]))
        sched.absorb(meta[1], None)
        work, meta = sched.plan_round()
        # r0 decodes in the same round as r1's second chunk
        assert {type(w).__name__ for w in work} == {"DecodeWork",
                                                    "PrefillChunkWork"}
        chunk = [w for w in work if isinstance(w, PrefillChunkWork)][0]
        assert not chunk.final and int(np.asarray(chunk.pos0)[0]) == 3

    def test_prefill_chunk_requires_paged(self, serve_env):
        cfg, mesh, params, _ = serve_env
        with pytest.raises(ValueError, match="prefill_chunk"):
            api.compile(cfg, mode="serve", prefill_chunk=3,
                        **_kw(params, mesh))


class TestSharedPrefix:
    def test_identical_prompts_share_pages(self, serve_env):
        """With a long-lived donor, later identical prompts map the
        page-aligned common prefix instead of re-storing it — and still
        emit the dense token streams."""
        cfg, mesh, params, prompts = serve_env
        reqs = [(prompts[0], 6), (prompts[0], 3), (prompts[0], 3),
                (prompts[0], 4)]
        dense = api.compile(cfg, mode="serve", backend="monolithic",
                            **_kw(params, mesh))
        ref = dense.generate(reqs)
        shr = api.compile(cfg, mode="serve", backend="monolithic",
                          cache="paged", page_len=PAGE_LEN, num_pages=16,
                          **_kw(params, mesh))
        outs = shr.generate(reqs)
        for i, (got, want) in enumerate(zip(outs, ref)):
            assert np.array_equal(got, want), f"request {i}"
        assert shr.last_stats["shared_pages"] > 0

    def test_disjoint_prompts_share_nothing(self, serve_env, dense_ref):
        cfg, mesh, params, prompts = serve_env
        sess = api.compile(cfg, mode="serve", backend="monolithic",
                           cache="paged", page_len=PAGE_LEN,
                           num_pages=NUM_PAGES, **_kw(params, mesh))
        outs = sess.generate(list(zip(prompts, GENS)))
        for got, want in zip(outs, dense_ref):
            assert np.array_equal(got, want)
        assert sess.last_stats["shared_pages"] == 0


class TestCacheBytes:
    def test_paged_pool_halves_cache_bytes(self, serve_env):
        """The headline arithmetic: at 4 slots, the paged pool sized for
        the realistic in-flight load holds under half the dense
        worst-case reservation."""
        cfg, mesh, params, _ = serve_env
        kw = dict(params=params, mesh=mesh, num_groups=2, group_size=2,
                  max_prompt_len=PROMPT_LEN, max_new_tokens=max(GENS),
                  cache_len=CACHE_LEN)
        dense = api.compile(cfg, mode="serve", backend="monolithic", **kw)
        paged = api.compile(cfg, mode="serve", backend="monolithic",
                            cache="paged", page_len=PAGE_LEN, num_pages=8,
                            **kw)
        assert paged.cache_bytes() * 2 <= dense.cache_bytes()

    def test_default_num_pages_matches_dense_capacity(self, serve_env):
        """Without num_pages=, the pool holds exactly the dense capacity
        (every slot at full cache_len) — same bytes, any length mix."""
        cfg, mesh, params, _ = serve_env
        sess = api.compile(cfg, mode="serve", backend="monolithic",
                           cache="paged", page_len=PAGE_LEN,
                           **_kw(params, mesh))
        spec = sess.cache_spec
        assert spec.num_pages * spec.page_len == 2 * 1 * CACHE_LEN


class TestPagedValidation:
    def test_page_len_must_divide_cache_len(self, serve_env):
        cfg, mesh, params, _ = serve_env
        with pytest.raises(ValueError, match="page_len"):
            api.compile(cfg, mode="serve", cache="paged", page_len=5,
                        **_kw(params, mesh))

    def test_pool_must_hold_one_worst_case_request(self, serve_env):
        cfg, mesh, params, _ = serve_env
        with pytest.raises(ValueError, match="num_pages"):
            api.compile(cfg, mode="serve", cache="paged", page_len=PAGE_LEN,
                        num_pages=2, **_kw(params, mesh))

    def test_paged_options_require_paged_cache(self, serve_env):
        cfg, mesh, params, _ = serve_env
        for bad in ({"page_len": 4}, {"num_pages": 8},
                    {"prefill_chunk": 3}):
            with pytest.raises(ValueError, match="cache='paged'"):
                api.compile(cfg, mode="serve", **bad, **_kw(params, mesh))

    def test_unknown_cache_kind(self, serve_env):
        cfg, mesh, params, _ = serve_env
        with pytest.raises(ValueError, match="dense.*paged|paged.*dense"):
            api.compile(cfg, mode="serve", cache="virtual",
                        **_kw(params, mesh))

    def test_spec_geometry_must_match_cache_len(self):
        from repro.serve.paged_cache import PagedStageCache

        spec = PagedCacheSpec(page_len=4, num_pages=8, max_requests=2,
                              pages_per_req=5)
        with pytest.raises(ValueError, match="cache_len"):
            PagedStageCache(stage=None, group_size=1, cache_len=24,
                            spec=spec)
