"""Property: the static deadlock verdict agrees with the real runtime.

Both oracle directions, on randomly generated bounded-buffer actor DAGs:

* analyzer says PASS  -> the ThreadedRuntime drives the network to
  completion (every bounded actor exhausts its fire budget);
* analyzer says DEADLOCK -> the same network wedges and the runtime's
  watchdog raises TimeoutError.

Plus the trace-sanitizer property: under random DelayEdge/DuplicateReq
fault plans on a real 1F1B train pipeline, the recorded Req trace still
replays in canonical per-channel order (the resequencer absorbed every
fault) and the vector-clock happens-before check holds.
"""
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (see requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro import api
from repro.analysis.deadlock import check_deadlock
from repro.analysis.trace import TraceRecorder, check_trace
from repro.core.graph import LogicalGraph
from repro.core.lowering import OptimizerSpec
from repro.core.placement import Placement
from repro.runtime.actor import ActorSpec
from repro.runtime.chaos import DelayEdge, DuplicateReq, FaultPlan
from repro.runtime.threaded import ThreadedRuntime

FIRES = 4  # fire budget for every generated actor


def _noop(*args):
    return 0


@st.composite
def _dags(draw):
    """A bounded source plus 2..5 actors, each consuming a nonempty subset
    of the actors before it (so the network is a connected-enough DAG).

    Fire budgets are drawn around the *rate-consistent* value (the most the
    actor's slowest input channel can feed it), so the sampler lands on both
    sides of the verdict: exact budgets give live networks (modulo quota
    starvation from shared producers), over-budgets give starvation, and
    tight quotas with fan-out give genuine quota-starved cycles."""
    n = draw(st.integers(2, 5))
    specs = [ActorSpec("a0", fn=_noop, inputs=(),
                       out_regs=draw(st.integers(1, 2)), max_fires=FIRES)]
    emissions = {"a0": FIRES}
    for i in range(1, n + 1):
        k = draw(st.integers(1, min(2, i)))
        inputs = tuple(sorted(draw(st.lists(
            st.sampled_from([f"a{j}" for j in range(i)]),
            min_size=k, max_size=k, unique=True))))
        emit_every = draw(st.sampled_from((1, 1, 1, 2)))
        feasible = min(emissions[p] for p in inputs)
        max_fires = max(1, draw(st.sampled_from(
            (feasible, feasible, feasible, feasible - 1, feasible + 1))))
        specs.append(ActorSpec(
            f"a{i}", fn=_noop, inputs=inputs,
            out_regs=draw(st.integers(1, 2)),
            max_fires=max_fires, emit_every=emit_every))
        emissions[f"a{i}"] = max_fires // emit_every
    return specs


class TestDeadlockOracle:
    @settings(max_examples=20, deadline=None)
    @given(specs=_dags())
    def test_verdict_matches_threaded_runtime(self, specs):
        result = check_deadlock(specs)
        rt = ThreadedRuntime(specs)
        try:
            if result.ok:
                rt.run(timeout=20.0)
                assert rt.last_fired == dict(result.required)
            else:
                with pytest.raises(TimeoutError):
                    rt.run(timeout=1.0)
        finally:
            rt.close()


B, W, S, M = 8, 8, 2, 2

EDGES = [("f0", "f1"), ("f1", "b1"), ("b1", "b0"),
         ("b0", "opt0"), ("b1", "opt1")]


def _graph():
    placement = Placement(("d",), (1,), device_kind="cpu")
    g = LogicalGraph(placement)
    h = g.input("x", (B, W))
    labels = g.input("labels", (B,), dtype="int32")
    for i in range(S):
        w = g.input(f"w{i}", (W, W))
        h = g.matmul(h, w, name=f"mm{i}")
        if i < S - 1:
            h = g.unary(h, "relu", name=f"relu{i}")
    g.softmax_xent(h, labels, name="loss")
    return g


_edges = st.sampled_from(EDGES)

_delays = st.builds(
    lambda e, secs, ver: DelayEdge(e[0], e[1], seconds=secs, version=ver),
    _edges, st.floats(0.005, 0.04),
    st.one_of(st.none(), st.integers(0, M - 1)))

_dups = st.builds(
    lambda e, ver: DuplicateReq(e[0], e[1], version=ver),
    _edges, st.integers(0, M - 1))

_plans = st.lists(st.one_of(_delays, _dups), min_size=1, max_size=3).map(
    lambda fs: FaultPlan(tuple(fs)))


class TestTraceSanitizerProperty:
    @settings(max_examples=8, deadline=None)
    @given(plan=_plans)
    def test_resequencer_certified_under_chaos(self, plan):
        rng = np.random.default_rng(0)
        params = {f"w{i}": (rng.normal(size=(W, W)) * 0.1).astype(np.float32)
                  for i in range(S)}
        data = {"x": rng.normal(size=(B, W)).astype(np.float32),
                "labels": rng.integers(0, W, size=(B,)).astype(np.int32)}
        rec = TraceRecorder()
        sess = api.compile(_graph(), mode="train", stages=S,
                           params=dict(params),
                           optimizer=OptimizerSpec.adamw(lr=1e-3),
                           num_microbatches=M, faults=plan)
        try:
            sess.executor.trace = rec
            sess.step(**data)
            sess.step(**data)
            specs, _ = sess._engine._make_builder()()
            violations, stats = check_trace(rec, specs)
        finally:
            sess.close()
        assert violations == [], (plan, violations)
        assert stats.deliveries > 0
