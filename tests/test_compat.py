"""The jax compat shims (repro/compat.py).

``jax.lax.pvary`` does not exist on older jax versions (pre-vma); the shim
must resolve to the identity there so ``models/common.py:force_vary`` and the
train-step metrics path keep working (the `bench_parallelisms` known issue
from ROADMAP).
"""
import importlib

import jax
import jax.numpy as jnp

import repro.compat


class TestPvaryShim:
    def test_pvary_resolves_on_current_jax(self):
        # on a jax with jax.lax.pvary the shim is the real primitive
        if hasattr(jax.lax, "pvary"):
            assert repro.compat.pvary is jax.lax.pvary

    def test_pvary_falls_back_to_identity_without_jax_lax_pvary(
            self, monkeypatch):
        """Simulate an old jax: delete the attribute, reload the shim, and
        check pvary degrades to the identity (then restore)."""
        monkeypatch.delattr(jax.lax, "pvary", raising=False)
        try:
            importlib.reload(repro.compat)
            x = jnp.arange(3.0)
            out = repro.compat.pvary(x, ("data", "model"))
            assert out is x
        finally:
            monkeypatch.undo()
            importlib.reload(repro.compat)
        if hasattr(jax.lax, "pvary"):
            assert repro.compat.pvary is jax.lax.pvary

    def test_force_vary_routes_through_compat(self):
        """models/common.py must import the shim, not jax.lax directly —
        outside shard_map force_vary is a no-op either way."""
        import repro.models.common as common

        src = open(common.__file__).read()
        assert "from repro.compat import pvary" in src
        assert "jax.lax.pvary" not in src
        x = jnp.ones((2, 2))
        assert common.force_vary(x, ("data",)) is x  # no live axes -> no-op

    def test_train_steps_route_through_compat(self):
        import repro.train.steps as steps

        src = open(steps.__file__).read()
        assert "jax.lax.pvary" not in src
