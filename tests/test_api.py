"""The `repro.api` Session frontend (paper §2/§4: one compile step).

Acceptance criteria of the api_redesign tentpole, pinned down:

(a) one import drives all four modes: train/infer x actors/monolithic all
    produce Sessions whose outputs/losses/grads/params/opt-state are
    bit-identical across backends on a shared 4-stage graph;
(b) omitted declarative options (plan / partition / regs /
    microbatch_inputs) infer values that reproduce the explicit-argument
    results exactly;
(c) invalid combinations fail fast with a clear ValueError naming the
    offending option or key — including unknown/missing run()/step() input
    names on both the Sessions and the underlying executors;
(d) the historical entry points (`make_graph_train_step`,
    `make_pipeline_train_step`) are deprecated shims over `api.compile`
    with unchanged numerics.
"""
import numpy as np
import pytest

from repro import api
from repro.core.graph import LogicalGraph, partition_stages
from repro.core.lowering import OptimizerSpec
from repro.core.placement import Placement
from repro.core.planner import plan as plan_sbp

B, W, S, M = 16, 32, 4, 4


def _graph(batch=B, width=W, depth=S, with_loss=True):
    placement = Placement(("d",), (1,), device_kind="cpu")
    g = LogicalGraph(placement)
    h = g.input("x", (batch, width))
    if with_loss:
        labels = g.input("labels", (batch,), dtype="int32")
    for i in range(depth):
        w = g.input(f"w{i}", (width, width))
        h = g.matmul(h, w, name=f"mm{i}")
        if i < depth - 1:
            h = g.unary(h, "relu", name=f"relu{i}")
    if with_loss:
        g.softmax_xent(h, labels, name="loss")
    return g


def _params_and_data(g, seed=0):
    rng = np.random.default_rng(seed)
    params, data = {}, {}
    for t in g.inputs:
        if t.name.startswith("w"):
            params[t.name] = (rng.normal(size=t.shape) * 0.1).astype(np.float32)
        elif t.dtype == "int32":
            data[t.name] = rng.integers(0, W, size=t.shape).astype(np.int32)
        else:
            data[t.name] = rng.normal(size=t.shape).astype(np.float32)
    return params, data


class TestFourWayBitIdentity:
    def test_infer_actors_vs_monolithic(self):
        g = _graph(with_loss=False)
        params, data = _params_and_data(g)
        inputs = {**params, **data}
        pipe = api.compile(g, mode="infer", backend="actors", stages=S,
                           num_microbatches=M, microbatch_inputs=["x"])
        mono = api.compile(g, mode="infer", backend="monolithic",
                           num_microbatches=M, microbatch_inputs=["x"])
        api.assert_sessions_match(pipe, mono, inputs)
        # and the sinks are named
        out = pipe.run(**inputs)
        assert set(out) == {"mm3.out"}
        assert out["mm3.out"].shape == (B, W)

    def test_train_sgd_actors_vs_monolithic_multi_step(self):
        g = _graph()
        params, data = _params_and_data(g)
        pipe = api.compile(g, mode="train", backend="actors", stages=S,
                           params=dict(params), num_microbatches=M)
        mono = api.compile(g, mode="train", backend="monolithic",
                           params=dict(params), num_microbatches=M)
        api.assert_sessions_match(pipe, mono, data, steps=3)
        assert pipe.step_count == mono.step_count == 3
        assert pipe.opt_state is None and mono.opt_state is None

    def test_train_adamw_clip_schedule_actors_vs_monolithic(self):
        g = _graph()
        params, data = _params_and_data(g)
        opt = OptimizerSpec.adamw(lr=lambda s: 1e-3 * 0.8 ** s,
                                  grad_clip=1.0)
        pipe = api.compile(g, mode="train", backend="actors", stages=S,
                           params=dict(params), num_microbatches=M,
                           optimizer=opt)
        mono = api.compile(g, mode="train", backend="monolithic",
                           params=dict(params), num_microbatches=M,
                           optimizer=opt)
        api.assert_sessions_match(pipe, mono, data, steps=3)
        assert int(pipe.opt_state.step) == 3
        assert pipe.history[-1]["lr"] == pytest.approx(1e-3 * 0.8 ** 2)

    def test_mismatch_is_detected(self):
        """assert_sessions_match must actually fail on different numbers."""
        g = _graph()
        params, data = _params_and_data(g)
        p2 = {n: v + 1.0 for n, v in params.items()}
        a = api.compile(g, mode="train", backend="actors", stages=S,
                        params=params, num_microbatches=M)
        b = api.compile(g, mode="train", backend="monolithic",
                        params=p2, num_microbatches=M)
        with pytest.raises(AssertionError, match="disagree"):
            api.assert_sessions_match(a, b, data)


class TestOptionInference:
    def test_omitted_plan_partition_regs_match_explicit(self):
        g = _graph()
        params, data = _params_and_data(g)
        auto = api.compile(g, mode="train", stages=S, params=dict(params),
                           num_microbatches=M)
        explicit = api.compile(
            g, mode="train", params=dict(params), num_microbatches=M,
            plan=plan_sbp(g), partition=partition_stages(g, S),
            regs=list(auto.regs), microbatch_inputs=["x", "labels"],
            mesh=g.placement.to_mesh())
        assert auto.partition.stage_of == explicit.partition.stage_of
        assert auto.regs == explicit.regs
        assert auto.microbatch_inputs == ["x", "labels"]
        api.assert_sessions_match(auto, explicit, data, steps=2)

    def test_auto_regs_come_from_register_planning(self):
        g = _graph()
        params, _ = _params_and_data(g)
        sess = api.compile(g, mode="train", stages=S, params=dict(params),
                           num_microbatches=8)
        assert sess.reg_plan is not None
        assert sess.regs == sess.reg_plan.regs
        assert all(r >= 1 for r in sess.regs)

    def test_reg_policies(self):
        g = _graph()
        params, data = _params_and_data(g)
        for policy, want in (("1f1b", [S - s for s in range(S)]),
                             ("gpipe", [M] * S), ("serial", [1] * S)):
            sess = api.compile(g, mode="train", stages=S, params=dict(params),
                               num_microbatches=M, regs=policy)
            assert sess.regs == want, policy
        with pytest.raises(ValueError, match="regs policy"):
            api.compile(g, mode="train", stages=S, params=dict(params),
                        num_microbatches=M, regs="zigzag")

    def test_stage_annotations_drive_default_partition(self):
        placement = Placement(("d",), (1,), device_kind="cpu")
        g = LogicalGraph(placement)
        x = g.input("x", (8, 16))
        labels = g.input("labels", (8,), dtype="int32")
        w0, w1 = g.input("w0", (16, 16)), g.input("w1", (16, 16))
        with g.stage(0):
            h = g.unary(g.matmul(x, w0, name="mm0"), "relu", name="r0")
        with g.stage(1):
            g.softmax_xent(g.matmul(h, w1, name="mm1"), labels, name="loss")
        sess = api.compile(g, mode="infer", backend="actors")
        assert sess.partition.num_stages == 2

    def test_graph_compile_sugar(self):
        g = _graph(with_loss=False)
        params, data = _params_and_data(g)
        sess = g.compile(mode="infer", backend="monolithic")
        out = sess.run(**params, **data)
        assert set(out) == {"mm3.out"}

    def test_describe_reports_plan_partition_quotas(self):
        g = _graph()
        params, _ = _params_and_data(g)
        sess = api.compile(g, mode="train", stages=S, params=dict(params),
                           num_microbatches=M, regs="1f1b")
        rep = sess.describe()
        assert "stage partition" in rep and "SBP plan" in rep
        assert "regs=4" in rep and "regs=1" in rep      # 1F1B quotas S-s
        assert "optimizer: sgd" in rep
        mono = api.compile(g, mode="train", backend="monolithic",
                           params=dict(params), num_microbatches=M)
        assert "no stage partition" in mono.describe()


class TestCompileValidation:
    def test_infer_with_optimizer_raises(self):
        g = _graph()
        with pytest.raises(ValueError, match="optimizer"):
            api.compile(g, mode="infer", optimizer=OptimizerSpec.sgd())

    def test_infer_with_params_raises(self):
        g = _graph()
        params, _ = _params_and_data(g)
        with pytest.raises(ValueError, match="params"):
            api.compile(g, mode="infer", params=params)

    def test_infer_with_loss_raises(self):
        with pytest.raises(ValueError, match="loss"):
            api.compile(_graph(), mode="infer", loss="loss.out")

    def test_train_without_params_raises(self):
        with pytest.raises(ValueError, match="params"):
            api.compile(_graph(), mode="train")

    def test_unknown_mode_backend_raise(self):
        g = _graph()
        with pytest.raises(ValueError, match="mode"):
            api.compile(g, mode="serve")
        with pytest.raises(ValueError, match="backend"):
            api.compile(g, mode="infer", backend="xla")

    def test_params_not_graph_inputs_raise(self):
        g = _graph()
        params, _ = _params_and_data(g)
        params["w_typo"] = params["w0"]
        with pytest.raises(ValueError, match="w_typo"):
            api.compile(g, mode="train", params=params)

    def test_partition_stages_contradiction_raises(self):
        g = _graph()
        params, _ = _params_and_data(g)
        with pytest.raises(ValueError, match="contradicts"):
            api.compile(g, mode="train", params=dict(params),
                        partition=partition_stages(g, 4), stages=2)

    def test_microbatched_infer_needs_names(self):
        g = _graph(with_loss=False)
        with pytest.raises(ValueError, match="microbatch_inputs"):
            api.compile(g, mode="infer", num_microbatches=4)

    def test_monolithic_rejects_stage_meshes(self):
        g = _graph(with_loss=False)
        with pytest.raises(ValueError, match="stage_meshes"):
            api.compile(g, mode="infer", backend="monolithic",
                        stage_meshes=[g.placement.to_mesh()])

    def test_monolithic_rejects_fn_wrap_but_accepts_schedule_hints(self):
        g = _graph(with_loss=False)
        with pytest.raises(ValueError, match="fn_wrap"):
            api.compile(g, mode="infer", backend="monolithic",
                        fn_wrap=lambda s, f: f)
        # schedule hints are accepted so one kwargs dict can sweep backends
        sess = api.compile(g, mode="infer", backend="monolithic",
                           stages=S, regs="1f1b")
        assert sess.partition is None and sess.regs is None

    def test_run_step_mode_mismatch(self):
        g = _graph()
        params, data = _params_and_data(g)
        train = api.compile(g, mode="train", stages=S, params=dict(params),
                            num_microbatches=M)
        infer = api.compile(_graph(with_loss=False), mode="infer",
                            backend="monolithic")
        with pytest.raises(RuntimeError, match="step"):
            train.run(**data)
        with pytest.raises(RuntimeError, match="run"):
            infer.step(x=data["x"])


class TestInputNameValidation:
    """Satellite: unknown/missing run/step inputs raise a ValueError naming
    the offending key instead of failing deep in actor bodies."""

    def _sessions(self):
        g = _graph()
        params, data = _params_and_data(g)
        pipe = api.compile(g, mode="train", backend="actors", stages=S,
                           params=dict(params), num_microbatches=M)
        mono = api.compile(g, mode="train", backend="monolithic",
                           params=dict(params), num_microbatches=M)
        return params, data, pipe, mono

    @pytest.mark.parametrize("backend", ["actors", "monolithic"])
    def test_step_unknown_and_missing_inputs(self, backend):
        params, data, pipe, mono = self._sessions()
        sess = pipe if backend == "actors" else mono
        with pytest.raises(ValueError, match="'junk'"):
            sess.step(**data, junk=data["x"])
        with pytest.raises(ValueError, match="'labels'"):
            sess.step(x=data["x"])

    @pytest.mark.parametrize("backend", ["actors", "monolithic"])
    def test_step_rejects_param_passed_as_data(self, backend):
        params, data, pipe, mono = self._sessions()
        sess = pipe if backend == "actors" else mono
        with pytest.raises(ValueError, match="'w0'.*owned by the executor"):
            sess.step(**data, w0=params["w0"])

    @pytest.mark.parametrize("backend", ["actors", "monolithic"])
    def test_infer_run_unknown_and_missing_inputs(self, backend):
        g = _graph(with_loss=False)
        params, data = _params_and_data(g)
        sess = api.compile(g, mode="infer", backend=backend,
                           **({"stages": S} if backend == "actors" else {}),
                           num_microbatches=M, microbatch_inputs=["x"])
        with pytest.raises(ValueError, match="'w9'"):
            sess.run(**params, **data, w9=params["w0"])
        with pytest.raises(ValueError, match="'x'"):
            sess.run(**params)

    def test_executors_validate_directly(self):
        """The underlying executors raise the same errors without a Session
        in front of them."""
        from repro.core.lowering import lower_stages, lower_train_stages
        from repro.runtime import (ActorPipelineExecutor,
                                   TrainPipelineExecutor)

        g = _graph(with_loss=False)
        params, data = _params_and_data(g)
        p = plan_sbp(g)
        part = partition_stages(g, S)
        mesh = g.placement.to_mesh()
        ex = ActorPipelineExecutor(lower_stages(g, p, part, mesh=mesh),
                                   ["x"], num_microbatches=M)
        with pytest.raises(ValueError, match="'bogus'"):
            ex.run({**params, **data, "bogus": data["x"]})
        with pytest.raises(ValueError, match="'w0'"):
            ex.run({"x": data["x"]})

        gt = _graph()
        tparams, tdata = _params_and_data(gt)
        tstaged = lower_train_stages(gt, plan_sbp(gt),
                                     partition_stages(gt, S), list(tparams),
                                     mesh=gt.placement.to_mesh())
        tex = TrainPipelineExecutor(tstaged, tparams, ["x", "labels"], M)
        with pytest.raises(ValueError, match="'mystery'"):
            tex.step({**tdata, "mystery": tdata["x"]})
        with pytest.raises(ValueError, match="'labels'"):
            tex.step({"x": tdata["x"]})


class TestDeprecatedShims:
    def test_make_graph_train_step_warns_and_matches_api(self):
        from repro.train.steps import make_graph_train_step

        g = _graph()
        params, data = _params_and_data(g)
        with pytest.warns(DeprecationWarning, match="api.compile"):
            mono = make_graph_train_step(g, g.placement.to_mesh(),
                                         list(params), ["x", "labels"],
                                         num_microbatches=M)
        sess = api.compile(g, mode="train", backend="monolithic",
                           params=dict(params), num_microbatches=M)
        cur = dict(params)
        for k in range(2):
            ml, mg, cur = mono.step(cur, data)
            res = sess.step(**data)
            assert bool(ml == res.loss)
            for n in params:
                assert np.array_equal(np.asarray(mg[n]),
                                      np.asarray(res.grads[n]))
                assert np.array_equal(np.asarray(cur[n]),
                                      np.asarray(res.params[n]))

    def test_make_pipeline_train_step_warns_and_returns_executor(self):
        from repro.runtime import TrainPipelineExecutor
        from repro.train.steps import make_pipeline_train_step

        g = _graph()
        params, data = _params_and_data(g)
        with pytest.warns(DeprecationWarning, match="api.compile"):
            pipe = make_pipeline_train_step(g, dict(params), ["x", "labels"],
                                            num_microbatches=M, num_stages=S,
                                            mesh=g.placement.to_mesh())
        assert isinstance(pipe, TrainPipelineExecutor)
        # historical default schedule preserved: 1F1B quotas S-s
        assert pipe.regs == [S - s for s in range(S)]
        loss, grads, new_params = pipe.step(data)
        assert np.isfinite(float(loss))


class TestSessionSurface:
    def test_history_and_metrics_accumulate(self):
        g = _graph()
        params, data = _params_and_data(g)
        sess = api.compile(g, mode="train", stages=S, params=dict(params),
                           num_microbatches=M)
        r0 = sess.step(**data)
        r1 = sess.step(**data)
        assert [h["step"] for h in sess.history] == [0, 1]
        assert r0.metrics["step"] == 0 and r1.metrics["step"] == 1
        assert r1.metrics["peak_inflight"] <= max(sess.regs)
        assert r1.metrics["makespan"] > 0
        # loss falls under SGD on this convex-ish toy
        assert float(r1.loss) < float(r0.loss)

    def test_load_params_restarts_trajectory(self):
        g = _graph()
        params, data = _params_and_data(g)
        a = api.compile(g, mode="train", stages=S, params=dict(params),
                        num_microbatches=M)
        b = api.compile(g, mode="train", stages=S, params=dict(params),
                        num_microbatches=M)
        a.step(**data)
        a.load_params(params)          # rewind to the initial weights
        ra, rb = a.step(**data), b.step(**data)
        assert bool(ra.loss == rb.loss)
        for n in params:
            assert np.array_equal(np.asarray(ra.params[n]),
                                  np.asarray(rb.params[n]))

    def test_top_level_reexports(self):
        import repro

        assert repro.compile is api.compile
        assert repro.Session is api.Session
        assert repro.assert_sessions_match is api.assert_sessions_match
