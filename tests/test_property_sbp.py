"""Hypothesis property tests on SBP invariants (pure logic, no devices)."""
import math

import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (see requirements-dev.txt)")

import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core.boxing import nd_transition_cost, transition_cost
from repro.core.placement import Placement
from repro.core.sbp import Broadcast, NdSbp, Partial, Split

COMPONENTS = [Split(0), Split(1), Broadcast(), Partial("sum")]
comp_st = st.sampled_from(COMPONENTS)
mesh_st = st.sampled_from([(2,), (4,), (2, 2), (2, 4), (4, 4), (2, 2, 2)])


@st.composite
def ndsbp_mesh(draw):
    mesh = draw(mesh_st)
    comps = tuple(draw(comp_st) for _ in mesh)
    return NdSbp(comps), mesh


@given(ndsbp_mesh())
def test_local_shape_conserves_elements(sm):
    """sum of shard elements x replicas == logical elements (for S/B axes)."""
    sig, mesh = sm
    shape = (16, 32)
    sig.validate_for_shape(shape, mesh)
    local = sig.local_shape(shape, mesh)
    n_dev = math.prod(mesh)
    shard_elems = math.prod(local)
    # every device holds shard_elems; splits tile the tensor, B and P replicate
    copies = 1
    for comp, size in zip(sig, mesh):
        if not comp.is_split:
            copies *= size
    assert shard_elems * n_dev == math.prod(shape) * copies


@given(ndsbp_mesh())
def test_transition_cost_non_negative_and_zero_iff_free(sm):
    sig, mesh = sm
    T = 4096.0
    for dst_comp in COMPONENTS:
        for k in range(len(mesh)):
            c = transition_cost(sig[k], dst_comp, T, mesh[k])
            assert c.volume >= 0
            if sig[k] == dst_comp:
                assert c.volume == 0


@given(ndsbp_mesh(), st.integers(0, 3))
def test_nd_cost_identity(sm, _):
    sig, mesh = sm
    assert nd_transition_cost(sig, sig, 8192.0, mesh) == 0.0


@given(ndsbp_mesh())
def test_nd_cost_monotone_in_bytes(sm):
    """cost scales linearly with tensor size."""
    sig, mesh = sm
    dst = NdSbp.broadcast(len(mesh))
    c1 = nd_transition_cost(sig, dst, 1000.0, mesh)
    c2 = nd_transition_cost(sig, dst, 2000.0, mesh)
    assert abs(c2 - 2 * c1) < 1e-6


@settings(deadline=None)  # first call imports jax.sharding lazily
@given(ndsbp_mesh())
def test_partition_spec_roundtrip(sm):
    """SBP -> PartitionSpec keeps sharded-axis structure (P excluded)."""
    sig, mesh = sm
    if sig.has_partial:
        return
    names = ("a", "b", "c")[: len(mesh)]
    pl = Placement(names, mesh)
    spec = pl.partition_spec(sig)
    # every split axis appears in the spec
    for comp, name in zip(sig, names):
        if comp.is_split:
            flat = []
            for e in spec:
                if isinstance(e, tuple):
                    flat.extend(e)
                elif e is not None:
                    flat.append(e)
            assert name in flat


@given(st.integers(2, 16), st.integers(1, 1 << 20))
def test_allreduce_equals_gather_plus_scatter(p, nbytes):
    """Table 2 consistency: all_reduce cost == reduce_scatter + all_gather."""
    ar = transition_cost(Partial("sum"), Broadcast(), float(nbytes), p).volume
    rs = transition_cost(Partial("sum"), Split(0), float(nbytes), p).volume
    ag = transition_cost(Split(0), Broadcast(), float(nbytes), p).volume
    assert abs(ar - (rs + ag)) < 1e-9
