"""1F1B training pipeline tests (paper §4.3/§6.5 for fwd+bwd+optimizer).

The acceptance criteria of the training tentpole, pinned down:

(a) pipelined gradients/losses/updated params are *bit-identical* to the
    monolithic SPMD ``make_graph_train_step`` over multiple steps;
(b) peak in-flight microbatches (forward registers in use) never exceed the
    register quota — serialized at R=1, 1F1B at R=S-s;
(c) optimizer actors fire exactly once per step (the accumulation actor
    consumes the per-microbatch gradient stream and emits once).
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.graph import LogicalGraph, partition_stages
from repro.core.lowering import lower_train_stages, split_microbatches
from repro.core.placement import Placement
from repro.core.planner import plan
from repro.runtime import ActorSpec, ThreadedRuntime
from repro.train.steps import make_graph_train_step, make_pipeline_train_step

B, W, DEPTH = 16, 32, 4


def _train_graph(depth=DEPTH, batch=B, width=W):
    """MLP + softmax cross-entropy: the loss sink is the only sink."""
    placement = Placement(("d",), (1,), device_kind="cpu")
    g = LogicalGraph(placement)
    h = g.input("x", (batch, width))
    labels = g.input("labels", (batch,), dtype="int32")
    for i in range(depth):
        w = g.input(f"w{i}", (width, width))
        h = g.matmul(h, w, name=f"mm{i}")
        if i < depth - 1:
            h = g.unary(h, "relu", name=f"relu{i}")
    g.softmax_xent(h, labels, name="loss")
    return g


def _params_and_data(g, seed=0, n_classes=None):
    rng = np.random.default_rng(seed)
    params, data = {}, {}
    for t in g.inputs:
        if t.name.startswith("w"):
            params[t.name] = (rng.normal(size=t.shape) * 0.1).astype(np.float32)
        elif t.dtype == "int32":
            hi = n_classes if n_classes is not None else W
            data[t.name] = rng.integers(0, hi, size=t.shape).astype(np.int32)
        else:
            data[t.name] = rng.normal(size=t.shape).astype(np.float32)
    return params, data


class TestBitIdentical:
    def test_pipeline_matches_monolithic_over_three_steps(self):
        """Criterion (a): same losses, gradients, and params, bitwise, for
        three consecutive optimizer steps."""
        g = _train_graph()
        params, data = _params_and_data(g)
        mesh = g.placement.to_mesh()
        mono = make_graph_train_step(g, mesh, list(params), ["x", "labels"],
                                     num_microbatches=4)
        pipe = make_pipeline_train_step(g, dict(params), ["x", "labels"],
                                        num_microbatches=4, num_stages=4,
                                        mesh=mesh)
        mono_params = dict(params)
        for step in range(3):
            ml, mg, mono_params = mono.step(mono_params, data)
            pl, pg, pipe_params = pipe.step(data)
            assert bool(ml == pl), f"loss diverged at step {step}"
            for n in params:
                assert bool(jnp.all(mg[n] == pg[n])), \
                    f"grad {n} diverged at step {step}"
                assert bool(jnp.all(mono_params[n] == pipe_params[n])), \
                    f"param {n} diverged at step {step}"

    def test_reference_step_matches_monolithic(self):
        """The sequential (non-actor) reference semantics of the staged
        training program agree bitwise with the monolithic step."""
        g = _train_graph()
        params, data = _params_and_data(g)
        mesh = g.placement.to_mesh()
        p = plan(g)
        part = partition_stages(g, num_stages=4)
        ts = lower_train_stages(g, p, part, list(params), mesh=mesh)
        rl, rg, rnew = ts.reference_step({**params, **data}, ["x", "labels"],
                                         num_microbatches=4)
        mono = make_graph_train_step(g, mesh, list(params), ["x", "labels"],
                                     num_microbatches=4)
        ml, mg, mnew = mono.step(dict(params), data)
        assert bool(rl == ml)
        for n in params:
            assert bool(jnp.all(rg[n] == mg[n]))
            assert bool(jnp.all(rnew[n] == mnew[n]))

    def test_skip_connection_across_stages(self):
        """A boundary activation consumed two stages downstream: its
        cotangent rides the backward chain and sums contributions from both
        consumers."""
        placement = Placement(("d",), (1,), device_kind="cpu")
        g = LogicalGraph(placement)
        x = g.input("x", (8, 16))
        labels = g.input("labels", (8,), dtype="int32")
        w0 = g.input("w0", (16, 16))
        w1 = g.input("w1", (16, 16))
        w2 = g.input("w2", (16, 16))
        with g.stage(0):
            h0 = g.unary(g.matmul(x, w0, name="mm0"), "relu", name="relu0")
        with g.stage(1):
            h1 = g.unary(g.matmul(h0, w1, name="mm1"), "relu", name="relu1")
        with g.stage(2):
            h2 = g.matmul(h1, w2, name="mm2")
            s = g.add(h2, h0, name="skip")       # h0 consumed at stage 2 too
            g.softmax_xent(s, labels, name="loss")
        params, data = _params_and_data(g, n_classes=16)
        mesh = g.placement.to_mesh()
        mono = make_graph_train_step(g, mesh, list(params), ["x", "labels"],
                                     num_microbatches=2)
        pipe = make_pipeline_train_step(g, dict(params), ["x", "labels"],
                                        num_microbatches=2, mesh=mesh)
        ml, mg, _ = mono.step(dict(params), data)
        pl, pg, _ = pipe.step(data)
        np.testing.assert_allclose(float(pl), float(ml), rtol=1e-6)
        for n in params:
            np.testing.assert_allclose(np.asarray(pg[n]), np.asarray(mg[n]),
                                       rtol=1e-5, atol=1e-6)


class TestMidGraphLoss:
    def test_loss_produced_before_last_stage(self):
        """The loss sink need not live on the last stage: the loss stream is
        collected at its producing stage's backward actor, and later stages
        (here a non-trained metric head) contribute zero cotangents."""
        placement = Placement(("d",), (1,), device_kind="cpu")
        g = LogicalGraph(placement)
        x = g.input("x", (8, 16))
        labels = g.input("labels", (8,), dtype="int32")
        w0 = g.input("w0", (16, 16))
        w_m = g.input("w_m", (16, 16))           # metric head, not trained
        with g.stage(0):
            h = g.matmul(x, w0, name="mm0")
            g.softmax_xent(h, labels, name="loss")
        with g.stage(1):
            g.unary(g.matmul(h, w_m, name="mm_m"), "tanh", name="metric")
        data = {"x": np.random.default_rng(0).normal(size=(8, 16))
                .astype(np.float32),
                "labels": np.random.default_rng(1).integers(0, 16, size=(8,))
                .astype(np.int32),
                "w_m": np.random.default_rng(2).normal(size=(16, 16))
                .astype(np.float32)}
        params = {"w0": (np.random.default_rng(3).normal(size=(16, 16)) * 0.1)
                  .astype(np.float32)}
        mesh = g.placement.to_mesh()
        mono = make_graph_train_step(g, mesh, ["w0"], ["x", "labels"],
                                     num_microbatches=2, loss="loss.out")
        pipe = make_pipeline_train_step(g, dict(params), ["x", "labels"],
                                        num_microbatches=2, mesh=mesh,
                                        loss="loss.out")
        ml, mg, _ = mono.step(dict(params), data)
        pl, pg, _ = pipe.step(data)
        assert bool(ml == pl)
        assert bool(jnp.all(mg["w0"] == pg["w0"]))


class TestRegisterQuota:
    def test_peak_inflight_never_exceeds_quota(self):
        """Criterion (b): forward registers in use are bounded by the quota
        for serialized (R=1), partial (R=2), and 1F1B (R=S-s) settings."""
        g = _train_graph()
        params, data = _params_and_data(g)
        mesh = g.placement.to_mesh()
        S, M = 4, 8
        for regs in ([1] * S, [2] * S, [S - s for s in range(S)]):
            pipe = make_pipeline_train_step(g, dict(params), ["x", "labels"],
                                            num_microbatches=M, num_stages=S,
                                            mesh=mesh, regs=regs)
            pipe.step(data)
            for s in range(S):
                assert pipe.last_peak_regs[f"f{s}"] <= regs[s]
            assert pipe.peak_inflight_activations <= max(regs)

    def test_serialized_quota_still_bit_identical(self):
        """R=1 fully serializes but must not change the numbers."""
        g = _train_graph()
        params, data = _params_and_data(g)
        mesh = g.placement.to_mesh()
        mono = make_graph_train_step(g, mesh, list(params), ["x", "labels"],
                                     num_microbatches=4)
        pipe = make_pipeline_train_step(g, dict(params), ["x", "labels"],
                                        num_microbatches=4, num_stages=4,
                                        mesh=mesh, regs=[1] * 4)
        ml, mg, _ = mono.step(dict(params), data)
        pl, pg, _ = pipe.step(data)
        assert bool(ml == pl)
        for n in params:
            assert bool(jnp.all(mg[n] == pg[n]))


class TestOptimizerActors:
    def test_optimizer_fires_exactly_once_per_step(self):
        """Criterion (c): each opt actor fires once; each backward and acc
        actor fires once per microbatch."""
        g = _train_graph()
        params, data = _params_and_data(g)
        mesh = g.placement.to_mesh()
        M, S = 8, 4
        pipe = make_pipeline_train_step(g, dict(params), ["x", "labels"],
                                        num_microbatches=M, num_stages=S,
                                        mesh=mesh)
        for _ in range(2):                       # per *step*, not just once
            pipe.step(data)
            for s in range(S):
                assert len(pipe.last_history[f"b{s}"]) == M
                if f"acc{s}" in pipe.last_history:
                    assert len(pipe.last_history[f"acc{s}"]) == M
                    assert len(pipe.last_history[f"opt{s}"]) == 1

    def test_emit_every_accumulation_actor(self):
        """ActorSpec.emit_every (OneFlow's acc op): consumes every firing,
        emits only each k-th output; the consumer fires once."""
        got = []
        specs = [
            ActorSpec("src", fn=lambda version: version + 1, inputs=(),
                      out_regs=2, max_fires=6, thread=0, wants_version=True),
            ActorSpec("acc", fn=_make_summer(), inputs=("src",), out_regs=1,
                      max_fires=6, thread=1, emit_every=6),
            ActorSpec("sink", fn=lambda total: got.append(total) or total,
                      inputs=("acc",), out_regs=1, max_fires=1, thread=2),
        ]
        rt = ThreadedRuntime(specs, collect_outputs_of="sink")
        outs = rt.run(timeout=10.0)
        assert outs == [21] and got == [21]      # 1+2+...+6
        assert rt.by_name["acc"].fired == 6
        assert rt.by_name["sink"].fired == 1
        assert not rt.by_name["acc"].refcount    # register recycled

    def test_suppressed_emits_are_not_collected(self):
        """Collecting an emit_every actor directly yields only the outputs
        the protocol actually emitted, not every fire's partial sum."""
        specs = [
            ActorSpec("src", fn=lambda version: version + 1, inputs=(),
                      out_regs=2, max_fires=6, thread=0, wants_version=True),
            ActorSpec("acc", fn=_make_summer(), inputs=("src",), out_regs=1,
                      max_fires=6, thread=1, emit_every=3),
        ]
        rt = ThreadedRuntime(specs, collect_outputs_of="acc")
        outs = rt.run(timeout=10.0)
        assert outs == [6, 21]                   # fires 3 and 6 only

    def test_annotated_graph_with_mismatched_num_stages_rejected(self):
        """An explicit num_stages must still be validated against stage
        annotations instead of being silently ignored."""
        placement = Placement(("d",), (1,), device_kind="cpu")
        g = LogicalGraph(placement)
        x = g.input("x", (8, 16))
        w0 = g.input("w0", (16, 16))
        with g.stage(0):
            h = g.matmul(x, w0, name="mm0")
        with g.stage(1):
            g.reduce(g.unary(h, "tanh", name="t"), axis=1, name="loss")
        with pytest.raises(ValueError, match="annotations span"):
            make_pipeline_train_step(g, {"w0": np.zeros((16, 16), np.float32)},
                                     ["x"], num_microbatches=2, num_stages=4,
                                     mesh=placement.to_mesh())

    def test_multi_actor_collection(self):
        """ThreadedRuntime collects from several actors at once, keyed by
        name (the training executor needs loss + every opt actor)."""
        specs = [
            ActorSpec("a", fn=lambda version: ("a", version), inputs=(),
                      out_regs=2, max_fires=3, thread=0, wants_version=True),
            ActorSpec("b", fn=lambda v: ("b", v[1]), inputs=("a",),
                      out_regs=2, max_fires=3, thread=1),
        ]
        rt = ThreadedRuntime(specs, collect_outputs_of=["a", "b"])
        outs = rt.run(timeout=10.0)
        assert set(outs) == {"a", "b"}
        assert [v for _, v in outs["a"]] == [0, 1, 2]
        assert [v for _, v in outs["b"]] == [0, 1, 2]


def _make_summer():
    state = {"total": 0}

    def run(x):
        state["total"] += x
        return state["total"]
    return run


class TestTrainLoweringValidation:
    def test_param_spanning_stages_rejected(self):
        placement = Placement(("d",), (1,), device_kind="cpu")
        g = LogicalGraph(placement)
        x = g.input("x", (8, 16))
        w = g.input("w", (16, 16))
        with g.stage(0):
            h = g.matmul(x, w, name="mm0")
        with g.stage(1):
            g.matmul(h, w, name="mm1")           # same param, second stage
        p = plan(g)
        part = partition_stages(g)
        with pytest.raises(ValueError, match="exactly one stage"):
            lower_train_stages(g, p, part, ["w"], mesh=placement.to_mesh())

    def test_loss_must_be_a_sink(self):
        g = _train_graph()
        p = plan(g)
        part = partition_stages(g, num_stages=2)
        with pytest.raises(ValueError, match="not a graph sink"):
            lower_train_stages(g, p, part, ["w0"], loss="mm0.out",
                               mesh=g.placement.to_mesh())

    def test_param_not_feeding_loss_rejected(self):
        placement = Placement(("d",), (1,), device_kind="cpu")
        g = LogicalGraph(placement)
        x = g.input("x", (8, 16))
        labels = g.input("labels", (8,), dtype="int32")
        w0 = g.input("w0", (16, 16))
        w_dead = g.input("w_dead", (16, 16))
        with g.stage(0):
            h = g.matmul(x, w0, name="mm0")
            g.unary(g.matmul(x, w_dead, name="mm_dead"), "tanh",
                    name="metric")                # sink, not the loss
        with g.stage(1):
            g.softmax_xent(h, labels, name="loss")
        p = plan(g)
        part = partition_stages(g)
        with pytest.raises(ValueError, match="does not feed the loss"):
            lower_train_stages(g, p, part, ["w0", "w_dead"], loss="loss.out",
                               mesh=placement.to_mesh())

    def test_non_input_param_rejected(self):
        g = _train_graph()
        p = plan(g)
        part = partition_stages(g, num_stages=2)
        with pytest.raises(ValueError, match="not a graph input"):
            lower_train_stages(g, p, part, ["nope"],
                               mesh=g.placement.to_mesh())

    def test_split_microbatches_rejects_indivisible(self):
        with pytest.raises(ValueError, match="not divisible"):
            split_microbatches({"x": np.zeros((10, 4))}, ["x"], 3)
