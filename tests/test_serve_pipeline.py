"""Serving-path tests: continuous-batching decode on the actor pipeline.

The reference semantics is the monolithic ``make_serve_step`` loop (one
batched prefill + whole-stack greedy decode). The pipelined ``ServeSession``
packs the same requests into decode slots, retires/admits mid-flight, and
must emit token-identical generations — including over a padded vocabulary
(vocab_size=1000 pads to 1024 logit columns) and requests with unequal
generation lengths.
"""
import dataclasses

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp

from repro import api
from repro.configs.registry import get_config
from repro.models.model_zoo import build_model
from repro.train.steps import (greedy_from_logits, make_serve_step,
                               plan_from_mesh)

PROMPT_LEN = 8
GENS = [3, 6, 2, 5, 4]          # unequal generation lengths
CACHE_LEN = 24


@pytest.fixture(scope="module")
def serve_env():
    cfg = get_config("qwen2.5-3b").reduced()
    # vocab 1000 pads to 1024: the head emits 24 junk logit columns that
    # greedy selection must never pick
    cfg = dataclasses.replace(cfg, vocab_size=1000)
    assert cfg.padded_vocab() > cfg.vocab_size
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    params = build_model(cfg, plan_from_mesh(mesh)).init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, (PROMPT_LEN,)).astype(np.int32)
               for _ in GENS]
    return cfg, mesh, params, prompts


@pytest.fixture(scope="module")
def reference_tokens(serve_env):
    """The monolithic make_serve_step loop over the fixed request set: one
    batched prefill, greedy decode to the longest request, per-request
    truncation. First-token logits go through logits_fn (the decode head)."""
    cfg, mesh, params, prompts = serve_env
    ss = make_serve_step(cfg, mesh, cache_len=CACHE_LEN)
    tokens = jnp.asarray(np.stack(prompts), jnp.int32)
    h_last, caches = ss.prefill_fn(params, {"tokens": tokens})
    tok = greedy_from_logits(ss.logits_fn(params, h_last), cfg.vocab_size)
    rows = [np.asarray(tok)]
    pos = jnp.full((len(GENS),), PROMPT_LEN, jnp.int32)
    for _ in range(max(GENS) - 1):
        logits, caches = ss.decode_fn(params, caches, tok, pos)
        tok = greedy_from_logits(logits, cfg.vocab_size)
        rows.append(np.asarray(tok))
        pos = pos + 1
    mat = np.stack(rows, 1)
    return [mat[i, :g] for i, g in enumerate(GENS)]


@pytest.fixture(scope="module")
def actor_session(serve_env):
    cfg, mesh, params, _ = serve_env
    return api.compile(cfg, mode="serve", backend="actors", stages=2,
                       params=params, mesh=mesh, num_groups=2, group_size=1,
                       max_prompt_len=PROMPT_LEN, max_new_tokens=max(GENS),
                       cache_len=CACHE_LEN)


@pytest.fixture(scope="module")
def mono_session(serve_env):
    cfg, mesh, params, _ = serve_env
    return api.compile(cfg, mode="serve", backend="monolithic",
                       params=params, mesh=mesh, num_groups=2, group_size=1,
                       max_prompt_len=PROMPT_LEN, max_new_tokens=max(GENS),
                       cache_len=CACHE_LEN)


class TestTokenIdentity:
    def test_pipeline_matches_monolithic_loop(self, serve_env, actor_session,
                                              reference_tokens):
        """5 requests through 2 slots: retirement + mid-flight admission,
        token-identical to the monolithic serve loop."""
        cfg, _, _, prompts = serve_env
        outs = actor_session.generate(list(zip(prompts, GENS)))
        assert [len(o) for o in outs] == GENS
        for i, (got, ref) in enumerate(zip(outs, reference_tokens)):
            assert np.array_equal(got, ref), (
                f"request {i}: pipeline {got} != monolithic loop {ref}")
        stats = actor_session.last_stats
        assert stats["admitted_mid_flight"] >= 1
        assert stats["tokens"] == sum(GENS)
        # padded-vocab columns never leak into the output
        assert all((o >= 0).all() and (o < cfg.vocab_size).all()
                   for o in outs)

    def test_monolithic_backend_matches_loop(self, serve_env, mono_session,
                                             reference_tokens):
        cfg, _, _, prompts = serve_env
        outs = mono_session.generate(list(zip(prompts, GENS)))
        for got, ref in zip(outs, reference_tokens):
            assert np.array_equal(got, ref)
        assert mono_session.last_stats["admitted_mid_flight"] >= 1

    def test_unequal_prompt_lengths_backends_agree(self, serve_env,
                                                   actor_session,
                                                   mono_session):
        """Prompts of different lengths run at their natural length (one
        prefill specialization each); the two backends must agree on every
        token."""
        cfg, _, _, prompts = serve_env
        reqs = [(prompts[0][:5], 3), (prompts[1], 4), (prompts[2][:7], 2)]
        a = actor_session.generate(reqs)
        b = mono_session.generate(reqs)
        for got, ref in zip(a, b):
            assert np.array_equal(got, ref)
        assert all((o < cfg.vocab_size).all() for o in a)

    def test_history_and_describe(self, actor_session):
        rep = actor_session.describe()
        assert "mode=serve" in rep and "backend=actors" in rep
        assert "stage 0" in rep and "stage 1" in rep
        kinds = {h["kind"] for h in actor_session.history}
        assert kinds == {"round", "generate"}


class TestSSMServe:
    def test_ssm_unequal_prompt_lengths_match_loop(self):
        """Recurrent SSM state makes prompt padding a correctness hazard
        (padding tokens would flow through the recurrence): prompts must run
        at their natural length. Each request is checked against its own
        monolithic B=1 serve loop."""
        cfg = get_config("mamba2-370m").reduced()
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        params = build_model(cfg, plan_from_mesh(mesh)).init(
            jax.random.PRNGKey(0))
        rng = np.random.default_rng(2)
        reqs = [(rng.integers(0, cfg.vocab_size, (n,)).astype(np.int32), g)
                for n, g in ((5, 3), (8, 2))]

        ss = make_serve_step(cfg, mesh, cache_len=CACHE_LEN)
        ref = []
        for prompt, gen in reqs:
            h_last, caches = ss.prefill_fn(params, {"tokens": prompt[None]})
            tok = greedy_from_logits(ss.logits_fn(params, h_last),
                                     cfg.vocab_size)
            toks = [int(tok[0])]
            pos = jnp.asarray([prompt.size], jnp.int32)
            for _ in range(gen - 1):
                logits, caches = ss.decode_fn(params, caches, tok, pos)
                tok = greedy_from_logits(logits, cfg.vocab_size)
                toks.append(int(tok[0]))
                pos = pos + 1
            ref.append(np.asarray(toks, np.int32))

        sess = api.compile(cfg, mode="serve", backend="actors",
                           params=params, mesh=mesh, num_groups=2,
                           group_size=1, max_prompt_len=8,
                           max_new_tokens=3, cache_len=CACHE_LEN)
        outs = sess.generate(reqs)
        for i, (got, want) in enumerate(zip(outs, ref)):
            assert np.array_equal(got, want), (
                f"ssm request {i}: {got} != {want}")


class TestGreedyHead:
    def test_greedy_masks_padded_vocab(self):
        """argmax over raw padded logits can emit junk ids >= vocab_size;
        greedy_from_logits must never."""
        V, Vp = 1000, 1024
        logits = np.zeros((3, Vp), np.float32)
        logits[:, 1010] = 5.0          # junk column wins the raw argmax
        logits[:, 7] = 1.0
        raw = np.asarray(jnp.argmax(jnp.asarray(logits), -1))
        assert (raw >= V).all()
        masked = np.asarray(greedy_from_logits(logits, V))
        assert (masked == 7).all()

    def test_prefill_logits_through_decode_head(self, serve_env):
        """ServeStep.logits_fn is the decode-step head: same math, same
        dtype, same model-sharded output — not a host-side h @ unembed."""
        cfg, mesh, params, prompts = serve_env
        ss = make_serve_step(cfg, mesh, cache_len=CACHE_LEN)
        tokens = jnp.asarray(np.stack(prompts), jnp.int32)
        h_last, caches = ss.prefill_fn(params, {"tokens": tokens})
        logits0 = ss.logits_fn(params, h_last)
        assert logits0.shape == (len(prompts), cfg.padded_vocab())
        # decode-step logits for the next position have the same dtype and
        # shape — the two heads are the same program modulo the input token
        tok = greedy_from_logits(logits0, cfg.vocab_size)
        pos = jnp.full((len(prompts),), PROMPT_LEN, jnp.int32)
        logits1, _ = ss.decode_fn(params, caches, tok, pos)
        assert logits1.dtype == logits0.dtype
        assert logits1.shape == logits0.shape
        # and it matches the explicit head math bit for bit
        want = h_last[:, 0] @ params["unembed"].astype(h_last.dtype)
        assert np.array_equal(np.asarray(logits0), np.asarray(want))


class TestServeValidation:
    def test_serve_rejects_graph_mode_options(self, serve_env):
        cfg, mesh, params, _ = serve_env
        from repro.core.lowering import OptimizerSpec
        with pytest.raises(ValueError, match="optimizer"):
            api.compile(cfg, mode="serve", optimizer=OptimizerSpec.sgd())
        with pytest.raises(ValueError, match="num_microbatches"):
            api.compile(cfg, mode="serve", num_microbatches=4)

    def test_graph_modes_reject_serve_options(self):
        from repro.core.placement import Placement
        from repro.core.graph import LogicalGraph
        placement = Placement(("d",), (1,), device_kind="cpu")
        g = LogicalGraph(placement)
        x = g.input("x", (4, 4))
        w = g.input("w", (4, 4))
        g.matmul(x, w, name="mm")
        with pytest.raises(ValueError, match="group_size"):
            api.compile(g, mode="infer", backend="monolithic", group_size=2)

    def test_serve_needs_token_frontend(self):
        with pytest.raises(ValueError, match="token frontend"):
            api.compile(get_config("pixtral-12b").reduced(), mode="serve")
        with pytest.raises(ValueError, match="token frontend"):
            api.compile(get_config("whisper-medium").reduced(), mode="serve")

    def test_serve_rejects_bad_geometry(self, serve_env):
        cfg, mesh, params, _ = serve_env
        with pytest.raises(ValueError, match="cache_len"):
            api.compile(cfg, mode="serve", max_prompt_len=8,
                        max_new_tokens=8, cache_len=16)
        with pytest.raises(ValueError, match="num_stages"):
            api.compile(cfg, mode="serve", stages=99, params=params,
                        mesh=mesh)
        with pytest.raises(ValueError, match="whole stack"):
            api.compile(cfg, mode="serve", backend="monolithic", stages=2)

    def test_zero_quota_fails_fast(self, serve_env):
        cfg, mesh, params, _ = serve_env
        with pytest.raises(ValueError, match=r"stage 1 .* got 0"):
            api.compile(cfg, mode="serve", backend="actors", stages=2,
                        params=params, mesh=mesh, regs=[1, 0],
                        max_prompt_len=PROMPT_LEN,
                        max_new_tokens=2, cache_len=CACHE_LEN)

    def test_generate_validates_requests(self, actor_session):
        with pytest.raises(ValueError, match="prompt length"):
            actor_session.generate(
                [(np.zeros(PROMPT_LEN + 1, np.int32), 1)])
        with pytest.raises(ValueError, match="max_new_tokens"):
            actor_session.generate(
                [(np.zeros(4, np.int32), max(GENS) + 1)])
        with pytest.raises(ValueError, match="non-empty"):
            actor_session.generate([(np.zeros(0, np.int32), 1)])


class TestAdmissionEdgeCases:
    def test_empty_request_list(self, mono_session):
        outs = mono_session.generate([])
        assert outs == []
        assert mono_session.last_stats["requests"] == 0
        assert mono_session.last_stats["tokens"] == 0

    def test_more_requests_than_slots(self, serve_env, actor_session,
                                      mono_session):
        """6 requests over 2 decode slots: everything beyond the first two
        waits in the admission queue and lands mid-flight, FIFO."""
        cfg, mesh, params, prompts = serve_env
        reqs = [(prompts[i % len(prompts)], 2 + i % 3) for i in range(6)]
        a = actor_session.generate(reqs)
        b = mono_session.generate(reqs)
        assert [len(o) for o in a] == [2 + i % 3 for i in range(6)]
        for i, (x, y) in enumerate(zip(a, b)):
            assert np.array_equal(x, y), f"request {i}: {x} != {y}"
        assert mono_session.last_stats["admitted_mid_flight"] == 4

    def test_prompt_exactly_max_prompt_len(self, serve_env, mono_session):
        """The boundary length is admissible; one past it is not (the
        rejection is covered in TestServeValidation)."""
        cfg, mesh, params, prompts = serve_env
        assert prompts[0].size == mono_session.max_prompt_len
        outs = mono_session.generate([(prompts[0], 3)])
        assert len(outs) == 1 and outs[0].shape == (3,)

    def test_all_requests_retire_same_round(self, serve_env, actor_session,
                                            mono_session):
        """Both slots retire in the same round; the scheduler must drain
        cleanly with nothing left to admit."""
        cfg, mesh, params, prompts = serve_env
        reqs = [(prompts[0], 3), (prompts[1], 3)]
        a = actor_session.generate(reqs)
        b = mono_session.generate(reqs)
        for x, y in zip(a, b):
            assert np.array_equal(x, y)
        assert [len(o) for o in a] == [3, 3]
        assert mono_session.last_stats["admitted_mid_flight"] == 0


class TestSamplerStream:
    def _spec(self, **over):
        from repro.serve import SamplingSpec
        kw = dict(temperature=0.8, top_k=50, top_p=0.95, seed=7)
        kw.update(over)
        return SamplingSpec(**kw)

    def _session(self, serve_env, **over):
        cfg, mesh, params, _ = serve_env
        kw = dict(params=params, mesh=mesh, num_groups=2, group_size=1,
                  max_prompt_len=PROMPT_LEN, max_new_tokens=max(GENS),
                  cache_len=CACHE_LEN)
        kw.update(over)
        return api.compile(cfg, mode="serve", **kw)

    def test_temperature_zero_is_bitwise_greedy(self, serve_env,
                                                mono_session):
        """temperature=0 routes through greedy_from_logits itself, so the
        stream is bit-identical to the unsampled session."""
        cfg, mesh, params, prompts = serve_env
        reqs = list(zip(prompts, GENS))
        sess = self._session(serve_env, backend="monolithic",
                             sampling=self._spec(temperature=0))
        got = sess.generate(reqs)
        want = mono_session.generate(reqs)
        for i, (x, y) in enumerate(zip(got, want)):
            assert np.array_equal(x, y), f"request {i}: {x} != {y}"

    def test_fixed_seed_actors_match_monolithic(self, serve_env):
        """One RNG register stream keyed only by round order and slot id:
        the actor pipeline must replay the monolithic stream exactly."""
        cfg, mesh, params, prompts = serve_env
        reqs = list(zip(prompts, GENS))
        mono = self._session(serve_env, backend="monolithic",
                             sampling=self._spec())
        want = mono.generate(reqs)
        with self._session(serve_env, backend="actors", stages=2,
                           sampling=self._spec()) as sess:
            got = sess.generate(reqs)
        for i, (x, y) in enumerate(zip(got, want)):
            assert np.array_equal(x, y), f"request {i}: {x} != {y}"
        assert all((o >= 0).all() and (o < cfg.vocab_size).all()
                   for o in want)
        # a different seed must change at least one stream
        other = self._session(serve_env, backend="monolithic",
                              sampling=self._spec(seed=8)).generate(reqs)
        assert any(not np.array_equal(x, y) for x, y in zip(want, other))

    def test_fixed_seed_threads_match_processes(self, serve_env):
        """The sampler key lives in the last stage's worker; thread and
        process runtimes must emit identical streams for the same seed."""
        cfg, mesh, params, prompts = serve_env
        reqs = list(zip(prompts, GENS))
        with self._session(serve_env, backend="actors", stages=2,
                           sampling=self._spec()) as thr:
            a = thr.generate(reqs)
        with self._session(serve_env, backend="actors", stages=2,
                           runtime="processes",
                           sampling=self._spec()) as proc:
            b = proc.generate(reqs)
        for i, (x, y) in enumerate(zip(a, b)):
            assert np.array_equal(x, y), f"request {i}: {x} != {y}"

    def test_sampling_spec_validation(self, serve_env):
        from repro.serve import SamplingSpec
        with pytest.raises(ValueError, match="temperature"):
            SamplingSpec(temperature=-0.5)
        with pytest.raises(ValueError, match="top_k"):
            SamplingSpec(top_k=-1)
        with pytest.raises(ValueError, match="top_p"):
            SamplingSpec(top_p=0.0)
        cfg, mesh, params, _ = serve_env
        with pytest.raises(ValueError, match="SamplingSpec"):
            self._session(serve_env, sampling="nucleus")


class TestServeOptionValidation:
    def test_geometry_error_names_all_three_options(self, serve_env):
        """Satellite: the compile-time budget check must name every knob
        the user could turn."""
        cfg, mesh, params, _ = serve_env
        with pytest.raises(ValueError) as e:
            api.compile(cfg, mode="serve", max_prompt_len=12,
                        max_new_tokens=12, cache_len=24)
        msg = str(e.value)
        for name in ("max_prompt_len", "max_new_tokens", "cache_len"):
            assert name in msg, f"{name!r} missing from: {msg}"

    def test_tiny_cache_len_names_parking_slot(self, serve_env):
        """cache_len < 2 leaves no room for the parking position
        (cache_len - 1); the lowering error says so explicitly."""
        cfg, mesh, params, _ = serve_env
        from repro.core.lowering import lower_serve_stages
        with pytest.raises(ValueError, match="parking"):
            lower_serve_stages(cfg, mesh, params, num_stages=1,
                               cache_len=1, max_prompt_len=1, group_size=1)
