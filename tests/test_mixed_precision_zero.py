"""Mixed-precision ZeRO stream acceptance (paper §6.4 + Fig 14).

The contract: ``api.compile(graph, mode="train", zero=True,
precision="bf16", loss_scale=...)`` runs forward/backward in bfloat16 over
flat fp32 master shards held by the opt actors, and is **bit-identical**
across every backend — actors/threads, actors/processes, monolithic — and
across the zero/dense layouts (the flat ``(dp, 1, chunk)`` shard is a pure
relayout of the dense fp32 master, and AdamW's math is elementwise).

Also covered here:

* static loss scaling (power-of-two: unscale-once is exact) and dynamic
  scaling via the ``scale`` actor — growth after ``growth_interval`` good
  steps, skip + backoff on a non-finite gradient norm, with the skipped
  step leaving params/moments/step-count untouched on every backend;
* bf16 payloads crossing node boundaries: ``encode_payload`` -> pickle ->
  decode must preserve ``bfloat16`` bitwise, including inside NamedTuples
  (``ZeroState``) — the processes runtime's wire format;
* option validation and ``describe()``/``opt_state_bytes()`` surfacing;
* snapshot/restore carrying the loss-scale trajectory.
"""
import pickle
import tempfile

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp

from repro import api
from repro.core.graph import LogicalGraph
from repro.core.lowering import OptimizerSpec, PrecisionPolicy
from repro.core.placement import Placement
from repro.optim.zero import ZeroState
from repro.runtime.base import encode_payload

B, W, S, M, STEPS = 8, 8, 2, 2, 3


def _graph():
    placement = Placement(("d",), (1,), device_kind="cpu")
    g = LogicalGraph(placement)
    h = g.input("x", (B, W))
    labels = g.input("labels", (B,), dtype="int32")
    for i in range(S):
        w = g.input(f"w{i}", (W, W))
        h = g.matmul(h, w, name=f"mm{i}")
        if i < S - 1:
            h = g.unary(h, "relu", name=f"relu{i}")
    g.softmax_xent(h, labels, name="loss")
    return g


def _params_and_data(seed=0):
    rng = np.random.default_rng(seed)
    params = {f"w{i}": (rng.normal(size=(W, W)) * 0.1).astype(np.float32)
              for i in range(S)}
    data = {"x": rng.normal(size=(B, W)).astype(np.float32),
            "labels": rng.integers(0, W, size=(B,)).astype(np.int32)}
    return params, data


def _lr_schedule(s):
    # module-level so the processes runtime can pickle it into workers
    return 1e-3 * 0.9 ** s


def _opt():
    return OptimizerSpec.adamw(lr=_lr_schedule, grad_clip=1.0)


def _mp_kwargs(params, **extra):
    kw = dict(mode="train", params=dict(params), optimizer=_opt(),
              num_microbatches=M, zero=True, precision="bf16",
              loss_scale=1024.0)
    kw.update(extra)
    return kw


class TestFourWayBitIdentity:
    """zero=True precision='bf16' loss_scale=1024: losses, fp32 masters and
    AdamW moments bitwise across all backend/runtime/layout combinations
    over STEPS scheduled-lr steps."""

    def test_actors_threads_vs_monolithic(self):
        params, data = _params_and_data()
        mono = api.compile(_graph(), backend="monolithic",
                           **_mp_kwargs(params))
        with api.compile(_graph(), backend="actors", stages=S,
                         runtime="threads", **_mp_kwargs(params)) as thr:
            api.assert_sessions_match(thr, mono, data, steps=STEPS)

    def test_actors_processes_vs_monolithic(self):
        params, data = _params_and_data()
        mono = api.compile(_graph(), backend="monolithic",
                           **_mp_kwargs(params))
        with api.compile(_graph(), backend="actors", stages=S,
                         runtime="processes", **_mp_kwargs(params)) as prc:
            api.assert_sessions_match(prc, mono, data, steps=STEPS)

    def test_zero_layout_matches_dense_masters(self):
        """The flat shard layout is pure bookkeeping: zero=True must equal
        zero=False at the same compute precision, bit for bit."""
        params, data = _params_and_data()
        z = api.compile(_graph(), backend="monolithic", **_mp_kwargs(params))
        d = api.compile(_graph(), backend="monolithic",
                        **_mp_kwargs(params, zero=False))
        api.assert_sessions_match(z, d, data, steps=STEPS)

    def test_masters_stay_fp32_params_surface_fp32(self):
        params, data = _params_and_data()
        with api.compile(_graph(), backend="actors", stages=S,
                         **_mp_kwargs(params)) as sess:
            res = sess.step(**data)
            for n, v in res.params.items():
                assert np.asarray(v).dtype == np.float32, n
            st = sess.opt_state
            for n in st.mu:
                assert np.asarray(st.mu[n]).dtype == np.float32
                assert np.asarray(st.nu[n]).dtype == np.float32

    def test_bf16_actually_degrades_vs_fp32(self):
        """Anti-placebo: the bf16 path must differ from full fp32 compute —
        otherwise the cast at the stage boundary is not happening."""
        params, data = _params_and_data()
        bf = api.compile(_graph(), backend="monolithic", **_mp_kwargs(params))
        fp = api.compile(_graph(), mode="train", backend="monolithic",
                         params=dict(params), optimizer=_opt(),
                         num_microbatches=M)
        lb = float(bf.step(**data).loss)
        lf = float(fp.step(**data).loss)
        assert lb != lf


class TestLossScaling:
    def test_static_scale_is_exact_for_powers_of_two(self):
        """Scaled-then-unscaled grads are bitwise equal to unscaled bf16
        training: scaling must cost nothing when nothing overflows."""
        params, data = _params_and_data()
        a = api.compile(_graph(), backend="monolithic", **_mp_kwargs(params))
        b = api.compile(_graph(), backend="monolithic",
                        **_mp_kwargs(params, loss_scale=None))
        api.assert_sessions_match(a, b, data, steps=STEPS)

    def test_metrics_carry_scale_and_skip(self):
        params, data = _params_and_data()
        sess = api.compile(_graph(), backend="monolithic",
                           **_mp_kwargs(params))
        m = sess.step(**data).metrics
        assert m["loss_scale"] == 1024.0
        assert m["skipped"] is False

    def _dynamic_policy(self, growth_interval=2):
        return PrecisionPolicy(compute_dtype="bfloat16", loss_scale="dynamic",
                               init_scale=2.0 ** 4,
                               growth_interval=growth_interval)

    def test_dynamic_growth_after_interval(self):
        params, data = _params_and_data()
        kw = _mp_kwargs(params, precision=self._dynamic_policy(),
                        loss_scale=None)
        mono = api.compile(_graph(), backend="monolithic", **kw)
        with api.compile(_graph(), backend="actors", stages=S, **kw) as thr:
            api.assert_sessions_match(thr, mono, data, steps=4)
            # 4 good steps at growth_interval=2 -> two doublings of 2**4
            assert mono.executor.loss_scale == 2.0 ** 6
            assert thr.executor.loss_scale == 2.0 ** 6

    @pytest.mark.parametrize("backend,runtime",
                             [("monolithic", None), ("actors", "threads"),
                              ("actors", "processes")])
    def test_nonfinite_step_skips_and_backs_off(self, backend, runtime):
        """An inf batch in bf16 produces a non-finite grad norm: the step
        must be skipped — params, moments and step counter untouched — and
        the scale halved, identically on every backend."""
        params, data = _params_and_data()
        bad = dict(data)
        bad["x"] = np.full_like(data["x"], np.inf)
        kw = _mp_kwargs(params, precision=self._dynamic_policy(),
                        loss_scale=None)
        if backend == "actors":
            kw.update(stages=S, runtime=runtime)
        with api.compile(_graph(), backend=backend, **kw) as sess:
            r0 = sess.step(**data)          # good step
            p_before = {n: np.asarray(v) for n, v in sess.params.items()}
            st_before = sess.opt_state
            r1 = sess.step(**bad)           # skipped step
            assert r1.metrics["skipped"] is True
            assert r1.grads == {}
            assert sess.step_count == 1     # schedule index did not advance
            assert sess.executor.loss_scale == 2.0 ** 3   # backed off
            for n, v in sess.params.items():
                np.testing.assert_array_equal(np.asarray(v), p_before[n],
                                              err_msg=n)
            assert int(sess.opt_state.step) == int(st_before.step)
            r2 = sess.step(**data)          # recovers at the lower scale
            assert r2.metrics["skipped"] is False
            assert r2.metrics["loss_scale"] == 2.0 ** 3
            assert r0.metrics["skipped"] is False

    def test_skip_trajectories_match_across_backends(self):
        params, data = _params_and_data()
        bad = dict(data)
        bad["x"] = np.full_like(data["x"], np.inf)
        kw = _mp_kwargs(params, precision=self._dynamic_policy(),
                        loss_scale=None)
        mono = api.compile(_graph(), backend="monolithic", **kw)
        with api.compile(_graph(), backend="actors", stages=S, **kw) as thr:
            for batch in (data, bad, data, data):
                rm, rt = mono.step(**batch), thr.step(**batch)
                assert rm.metrics["skipped"] == rt.metrics["skipped"]
                assert rm.metrics["loss_scale"] == rt.metrics["loss_scale"]
                if not rm.metrics["skipped"]:
                    assert float(rm.loss) == float(rt.loss)
            for n, v in mono.params.items():
                np.testing.assert_array_equal(np.asarray(thr.params[n]),
                                              np.asarray(v), err_msg=n)


class TestBf16WireFormat:
    """Satellite: bf16 arrays must survive the processes runtime's wire
    format — ``encode_payload`` -> pickle -> unpickle — bitwise, with the
    ``bfloat16`` dtype intact (ml_dtypes must not degrade to fp32/fp16)."""

    def _roundtrip(self, payload):
        return pickle.loads(pickle.dumps(encode_payload(payload)))

    def test_bf16_jax_array_roundtrips_bitwise(self):
        x = jnp.asarray(np.random.default_rng(0).normal(size=(7, 3)),
                        jnp.bfloat16)
        out = self._roundtrip({"x": x})["x"]
        assert out.dtype == np.asarray(x).dtype       # still bfloat16
        np.testing.assert_array_equal(
            out.view(np.uint16), np.asarray(x).view(np.uint16))

    def test_bf16_inside_zero_state_namedtuple(self):
        mk = lambda: jnp.asarray(  # noqa: E731
            np.random.default_rng(1).normal(size=(2, 1, 5)), jnp.float32)
        st = ZeroState(jnp.asarray(3, jnp.int32),
                       {"w": mk().astype(jnp.bfloat16)}, {"w": mk()})
        out = self._roundtrip({"state": st})["state"]
        assert isinstance(out, ZeroState)
        assert out.mu["w"].dtype == jnp.bfloat16
        np.testing.assert_array_equal(
            np.asarray(out.mu["w"]).view(np.uint16),
            np.asarray(st.mu["w"]).view(np.uint16))
        np.testing.assert_array_equal(np.asarray(out.nu["w"]),
                                      np.asarray(st.nu["w"]))
        assert int(out.step) == 3

    def test_private_keys_still_stripped(self):
        out = self._roundtrip({"__vjp__": object, "loss": 1.0})
        assert "__vjp__" not in out and out["loss"] == 1.0


class TestOptionValidation:
    def test_rejected_outside_train_mode(self):
        for kw in ({"zero": True}, {"precision": "bf16"},
                   {"loss_scale": 2.0}):
            with pytest.raises(ValueError, match="mode='train'"):
                api.compile(_graph(), mode="infer", **kw)

    def test_zero_requires_adamw(self):
        params, _ = _params_and_data()
        with pytest.raises(ValueError, match="adamw"):
            api.compile(_graph(), mode="train", params=dict(params),
                        zero=True)     # default SGD

    def test_zero_requires_a_data_axis(self):
        placement = Placement(("row", "col"), (1, 1), device_kind="cpu")
        g = LogicalGraph(placement)
        h = g.input("x", (B, W))
        labels = g.input("labels", (B,), dtype="int32")
        w = g.input("w0", (W, W))
        g.softmax_xent(g.matmul(h, w, name="mm0"), labels, name="loss")
        params = {"w0": np.zeros((W, W), np.float32)}
        with pytest.raises(ValueError, match="data axis"):
            api.compile(g, mode="train", params=params, optimizer=_opt(),
                        zero=True)

    def test_loss_scale_requires_bf16(self):
        params, _ = _params_and_data()
        with pytest.raises(ValueError, match="precision"):
            api.compile(_graph(), mode="train", params=dict(params),
                        optimizer=_opt(), loss_scale=2.0)
        with pytest.raises(ValueError, match="bfloat16"):
            api.compile(_graph(), mode="train", params=dict(params),
                        optimizer=_opt(), precision="fp32", loss_scale=2.0)

    def test_unknown_precision_string(self):
        params, _ = _params_and_data()
        with pytest.raises(ValueError, match="precision"):
            api.compile(_graph(), mode="train", params=dict(params),
                        optimizer=_opt(), precision="fp8")

    def test_bad_policy_values(self):
        with pytest.raises(ValueError):
            PrecisionPolicy(compute_dtype="float16")
        with pytest.raises(ValueError):
            PrecisionPolicy(loss_scale=-1.0)
        with pytest.raises(ValueError):
            PrecisionPolicy(loss_scale="sometimes")


class TestSurfacing:
    def test_describe_reports_precision_zero_and_bytes(self):
        params, data = _params_and_data()
        with api.compile(_graph(), backend="actors", stages=S,
                         **_mp_kwargs(params)) as sess:
            sess.step(**data)
            text = sess.describe()
        assert "precision: compute=bfloat16 masters=float32" in text
        assert "loss_scale=1024.0" in text
        assert "zero: dp=1" in text
        assert "optimizer-state bytes/device:" in text

    def test_opt_state_bytes_accounting(self):
        """Mixed precision holds masters+mu+nu fp32 (3 floats/element);
        plain AdamW holds mu+nu (2). N = S*W*W elements here, dp=1."""
        params, data = _params_and_data()
        n_elems = S * W * W
        with api.compile(_graph(), backend="actors", stages=S,
                         **_mp_kwargs(params)) as mp_sess:
            mp_sess.step(**data)
            mp_bytes = sum(mp_sess.executor.opt_state_bytes().values())
        with api.compile(_graph(), mode="train", backend="actors", stages=S,
                         params=dict(params), optimizer=_opt(),
                         num_microbatches=M) as dense_sess:
            dense_sess.step(**data)
            dense_bytes = sum(dense_sess.executor.opt_state_bytes().values())
        assert mp_bytes == 3 * 4 * n_elems
        assert dense_bytes == 2 * 4 * n_elems
        # both engines account identically
        mono = api.compile(_graph(), backend="monolithic",
                           **_mp_kwargs(params))
        mono.step(**data)
        assert sum(mono.executor.opt_state_bytes().values()) == mp_bytes

    def test_last_edge_bytes_surface(self):
        params, data = _params_and_data()
        with api.compile(_graph(), backend="actors", stages=S,
                         **_mp_kwargs(params)) as sess:
            sess.step(**data)
            eb = sess.last_edge_bytes
            assert eb and all(isinstance(v, int) for v in eb.values())
        mono = api.compile(_graph(), backend="monolithic",
                           **_mp_kwargs(params))
        assert mono.last_edge_bytes == {}


class TestSnapshotCarriesScale:
    def test_restore_resumes_scale_trajectory(self):
        """A snapshot taken under dynamic scaling records the scale to
        resume with; restore must continue the interrupted trajectory
        bitwise — including the scale the next step runs under."""
        params, data = _params_and_data()
        pol = PrecisionPolicy(compute_dtype="bfloat16", loss_scale="dynamic",
                              init_scale=2.0 ** 4, growth_interval=2)
        kw = _mp_kwargs(params, precision=pol, loss_scale=None)
        ref = api.compile(_graph(), backend="monolithic", **kw)
        ref_losses = [float(ref.step(**data).loss) for _ in range(4)]
        with tempfile.TemporaryDirectory() as d:
            with api.compile(_graph(), backend="actors", stages=S,
                             snapshot_dir=d, **kw) as sess:
                losses = [float(sess.step(**data).loss) for _ in range(2)]
            with api.compile(_graph(), backend="actors", stages=S,
                             restore=d, **kw) as res:
                # two good steps at growth_interval=2 -> scale grew once
                assert res.executor.loss_scale == 2.0 ** 5
                assert res.step_count == 2
                losses += [float(res.step(**data).loss) for _ in range(2)]
                final = res.params
        assert losses == ref_losses
        for n, v in ref.params.items():
            np.testing.assert_array_equal(np.asarray(final[n]),
                                          np.asarray(v), err_msg=n)
