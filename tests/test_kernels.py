"""Pallas kernel tests: shape/dtype sweeps vs the pure-jnp oracles
(interpret=True executes the kernel bodies on CPU)."""
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import (attention_dense_ref,
                                               flash_attention_ref)
from repro.kernels.flash_decode.kernel import flash_decode_pallas
from repro.kernels.flash_decode.ref import (
    combine_partials, decode_attention_ref)
from repro.kernels.softmax_xent.kernel import xent_local_stats_pallas
from repro.kernels.softmax_xent.ref import (combine_stats, local_stats_ref,
                                            softmax_xent_ref)
from repro.kernels.ssd_scan.kernel import ssd_scan_pallas
from repro.kernels.ssd_scan.ref import ssd_chunked_ref, ssd_sequential_ref

RNG = np.random.default_rng(42)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-4, atol=2e-5)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

FLASH_CASES = [
    # B, Sq, Sk, H, KV, D, Dv, causal, window, dtype
    (2, 50, 50, 4, 2, 16, 16, True, 0, jnp.float32),
    (1, 33, 33, 4, 4, 32, 16, True, 7, jnp.float32),     # MLA-ish Dv != D
    (2, 16, 64, 2, 1, 16, 16, False, 0, jnp.float32),    # cross attention
    (1, 128, 128, 8, 2, 64, 64, True, 0, jnp.bfloat16),
    (1, 17, 65, 2, 2, 8, 8, True, 0, jnp.float32),       # ragged + offset
]


@pytest.mark.parametrize("case", FLASH_CASES)
def test_flash_attention_kernel_vs_oracle(case):
    B, Sq, Sk, H, KV, D, Dv, causal, w, dt = case
    q = jnp.asarray(RNG.normal(size=(B, Sq, H, D)), dt)
    k = jnp.asarray(RNG.normal(size=(B, Sk, KV, D)), dt)
    v = jnp.asarray(RNG.normal(size=(B, Sk, KV, Dv)), dt)
    qoff = Sk - Sq if causal else 0
    got = flash_attention_pallas(q, k, v, causal=causal, sliding_window=w,
                                 q_offset=qoff, block_q=16, block_k=16)
    want = attention_dense_ref(q, k, v, causal=causal, sliding_window=w,
                               q_offset=qoff)
    assert_allclose(np.asarray(got, np.float32), np.asarray(want, np.float32),
                    **_tol(dt))


@pytest.mark.parametrize("blocks", [(16, 16), (32, 16), (16, 64)])
def test_flash_ref_block_invariance(blocks):
    """The jnp flash ref must be block-size invariant."""
    bq, bk = blocks
    q = jnp.asarray(RNG.normal(size=(2, 40, 4, 16)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(2, 40, 2, 16)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(2, 40, 2, 16)), jnp.float32)
    got = flash_attention_ref(q, k, v, causal=True, block_q=bq, block_k=bk)
    want = attention_dense_ref(q, k, v, causal=True)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-5)


# ---------------------------------------------------------------------------
# flash decode
# ---------------------------------------------------------------------------

DECODE_CASES = [
    (2, 4, 2, 16, 64, 0, jnp.float32),
    (1, 8, 8, 32, 100, 17, jnp.float32),
    (3, 4, 1, 64, 96, 0, jnp.bfloat16),
]


@pytest.mark.parametrize("case", DECODE_CASES)
def test_flash_decode_kernel_vs_oracle(case):
    B, H, KV, D, L, w, dt = case
    q = jnp.asarray(RNG.normal(size=(B, H, D)), dt)
    k = jnp.asarray(RNG.normal(size=(B, L, KV, D)), dt)
    v = jnp.asarray(RNG.normal(size=(B, L, KV, D)), dt)
    cur = jnp.asarray(RNG.integers(10, L, size=(B,)), jnp.int32)
    m1, l1, a1 = flash_decode_pallas(q, k, v, cur_pos=cur, sliding_window=w,
                                     block_k=16)
    o1 = a1 / jnp.maximum(l1, 1e-30)[..., None]
    want = decode_attention_ref(q, k, v, cur, sliding_window=w)
    assert_allclose(np.asarray(o1, np.float32), np.asarray(want, np.float32),
                    **_tol(dt))


def test_flash_decode_shard_combine():
    """Kernel partials from disjoint shards combine to the full attention —
    the P(max)/P(sum) algebra the distributed decode uses."""
    B, H, KV, D, L = 2, 4, 2, 16, 64
    q = jnp.asarray(RNG.normal(size=(B, H, D)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(B, L, KV, D)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(B, L, KV, D)), jnp.float32)
    cur = jnp.asarray([40, 63], jnp.int32)
    parts = [flash_decode_pallas(q, k[:, i*16:(i+1)*16], v[:, i*16:(i+1)*16],
                                 cur_pos=cur, k_offset=i*16, block_k=8)
             for i in range(4)]
    m = jnp.stack([p[0] for p in parts])
    l = jnp.stack([p[1] for p in parts])
    a = jnp.stack([p[2] for p in parts])
    got = combine_partials(m, l, a)
    want = decode_attention_ref(q, k, v, cur)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# softmax xent
# ---------------------------------------------------------------------------

XENT_CASES = [
    (64, 1000, 0, jnp.float32),
    (100, 700, 2100, jnp.float32),
    (7, 130, 130, jnp.float32),
    (256, 2048, 4096, jnp.bfloat16),
]


@pytest.mark.parametrize("case", XENT_CASES)
def test_xent_kernel_vs_oracle(case):
    N, Vl, off, dt = case
    logits = jnp.asarray(RNG.normal(size=(N, Vl)) * 3, dt)
    labels = jnp.asarray(RNG.integers(0, 3 * Vl, size=(N,)), jnp.int32)
    m1, s1, z1 = xent_local_stats_pallas(logits, labels, off, block_v=256)
    m2, s2, z2 = local_stats_ref(logits, labels, off)
    tol = _tol(dt)
    assert_allclose(np.asarray(m1), np.asarray(m2), **tol)
    assert_allclose(np.asarray(s1), np.asarray(s2), **tol)
    assert_allclose(np.asarray(z1), np.asarray(z2), **tol)


def test_xent_shard_combine_matches_full():
    """Four vocab shards' kernel stats combine to the dense softmax-xent."""
    N, V = 32, 1024
    logits = jnp.asarray(RNG.normal(size=(N, V)) * 2, jnp.float32)
    labels = jnp.asarray(RNG.integers(0, V, size=(N,)), jnp.int32)
    Vl = V // 4
    stats = [xent_local_stats_pallas(logits[:, i*Vl:(i+1)*Vl], labels, i*Vl,
                                     block_v=128) for i in range(4)]
    m = jnp.stack([s[0] for s in stats])
    s_ = jnp.stack([s[1] for s in stats])
    z = jnp.stack([s[2] for s in stats])
    got = combine_stats(m, s_, z)
    want = softmax_xent_ref(logits, labels)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# ssd scan
# ---------------------------------------------------------------------------

SSD_CASES = [
    (2, 67, 4, 8, 16, 1, 16, jnp.float32),
    (1, 128, 2, 16, 8, 2, 32, jnp.float32),
    (1, 64, 4, 32, 16, 1, 128, jnp.float32),   # chunk > L
    (2, 96, 4, 16, 16, 1, 32, jnp.bfloat16),
]


@pytest.mark.parametrize("case", SSD_CASES)
def test_ssd_kernel_vs_sequential_oracle(case):
    B, L, H, P, N, G, Q, dt = case
    x = jnp.asarray(RNG.normal(size=(B, L, H, P)), dt)
    dtv = jnp.asarray(RNG.uniform(0.01, 0.2, size=(B, L, H)), jnp.float32)
    A = jnp.asarray(-RNG.uniform(0.5, 2, size=(H,)), jnp.float32)
    Bm = jnp.asarray(RNG.normal(size=(B, L, G, N)), dt)
    Cm = jnp.asarray(RNG.normal(size=(B, L, G, N)), dt)
    D = jnp.asarray(RNG.normal(size=(H,)), jnp.float32)
    y1, h1 = ssd_scan_pallas(x, dtv, A, Bm, Cm, D, chunk=Q)
    y2, h2 = ssd_sequential_ref(x, dtv, A, Bm, Cm, D)
    tol = _tol(dt)
    assert_allclose(np.asarray(y1, np.float32), np.asarray(y2, np.float32),
                    **tol)
    assert_allclose(np.asarray(h1), np.asarray(h2),
                    rtol=max(tol["rtol"], 1e-4), atol=max(tol["atol"], 1e-4))


def test_ssd_chunked_ref_matches_sequential():
    B, L, H, P, N, G = 2, 77, 4, 8, 16, 1
    x = jnp.asarray(RNG.normal(size=(B, L, H, P)), jnp.float32)
    dtv = jnp.asarray(RNG.uniform(0.01, 0.2, size=(B, L, H)), jnp.float32)
    A = jnp.asarray(-RNG.uniform(0.5, 2, size=(H,)), jnp.float32)
    Bm = jnp.asarray(RNG.normal(size=(B, L, G, N)), jnp.float32)
    Cm = jnp.asarray(RNG.normal(size=(B, L, G, N)), jnp.float32)
    D = jnp.asarray(RNG.normal(size=(H,)), jnp.float32)
    y1, h1 = ssd_chunked_ref(x, dtv, A, Bm, Cm, D, chunk=16)
    y2, h2 = ssd_sequential_ref(x, dtv, A, Bm, Cm, D)
    assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-4, atol=1e-4)
    assert_allclose(np.asarray(h1), np.asarray(h2), rtol=1e-4, atol=1e-4)
