"""Property: message-level chaos never changes training bits.

The actor protocol's correctness story (§4.2) is that counters — not
arrival order — decide when an actor acts: a Req is consumed only when its
version is next for its channel, duplicates are dropped by the per-channel
resequencer, and back-pressure comes from register quotas. So randomly
delaying and duplicating Reqs on real edges of a 1F1B AdamW pipeline must
be invisible in the numbers: same losses, same final params, bit for bit.

(DropAck is deliberately excluded: a dropped ack is a *detected* fault —
the producer's register is never freed, the run wedges and times out — not
a reordering the protocol must absorb. test_fault_tolerance covers the
detected-fault path via kills.)
"""
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (see requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro import api
from repro.core.graph import LogicalGraph
from repro.core.lowering import OptimizerSpec
from repro.core.placement import Placement
from repro.runtime.chaos import DelayEdge, DuplicateReq, FaultPlan

B, W, S, M, STEPS = 8, 8, 2, 2, 3

#: real Req edges of the 2-stage train pipeline (fwd chain, bwd chain,
#: accumulated-grad hand-off to the optimizers)
EDGES = [("f0", "f1"), ("f1", "b1"), ("b1", "b0"),
         ("b0", "opt0"), ("b1", "opt1")]


def _graph():
    placement = Placement(("d",), (1,), device_kind="cpu")
    g = LogicalGraph(placement)
    h = g.input("x", (B, W))
    labels = g.input("labels", (B,), dtype="int32")
    for i in range(S):
        w = g.input(f"w{i}", (W, W))
        h = g.matmul(h, w, name=f"mm{i}")
        if i < S - 1:
            h = g.unary(h, "relu", name=f"relu{i}")
    g.softmax_xent(h, labels, name="loss")
    return g


_CACHE = {}


def _reference():
    if "ref" not in _CACHE:
        rng = np.random.default_rng(0)
        params = {f"w{i}": (rng.normal(size=(W, W)) * 0.1).astype(np.float32)
                  for i in range(S)}
        data = {"x": rng.normal(size=(B, W)).astype(np.float32),
                "labels": rng.integers(0, W, size=(B,)).astype(np.int32)}
        opt = OptimizerSpec.adamw(lr=1e-3, grad_clip=1.0)
        sess = api.compile(_graph(), mode="train", stages=S,
                           params=dict(params), optimizer=opt,
                           num_microbatches=M)
        losses = [float(sess.step(**data).loss) for _ in range(STEPS)]
        sess.close()
        _CACHE["ref"] = (params, data, opt, losses, sess.params)
    return _CACHE["ref"]


_edges = st.sampled_from(EDGES)

_delays = st.builds(
    lambda e, secs, ver: DelayEdge(e[0], e[1], seconds=secs, version=ver),
    _edges, st.floats(0.005, 0.04),
    st.one_of(st.none(), st.integers(0, M * STEPS - 1)))

_dups = st.builds(
    lambda e, ver: DuplicateReq(e[0], e[1], version=ver),
    _edges, st.integers(0, M * STEPS - 1))

_plans = st.lists(st.one_of(_delays, _dups), min_size=1, max_size=3).map(
    lambda fs: FaultPlan(tuple(fs)))


class TestChaosInvariance:
    @settings(max_examples=8, deadline=None)
    @given(plan=_plans)
    def test_delay_duplicate_never_change_bits(self, plan):
        params, data, opt, ref_losses, ref_params = _reference()
        sess = api.compile(_graph(), mode="train", stages=S,
                           params=dict(params), optimizer=opt,
                           num_microbatches=M, faults=plan)
        try:
            losses = [float(sess.step(**data).loss) for _ in range(STEPS)]
            final = sess.params
        finally:
            sess.close()
        assert losses == ref_losses, plan
        for n, v in ref_params.items():
            assert np.array_equal(np.asarray(final[n]), np.asarray(v)), \
                (n, plan)
